//! [`TildeApi`] implementations: the three ways a model body executes.

use rand_core::RngCore;

use crate::ad::Scalar;
use crate::context::{Accumulator, Context};
use crate::dist::{bijector, DiscreteDist, ScalarDist, VecDist};
use crate::value::Value;
use crate::varinfo::{flags, TypedVarInfo, UntypedVarInfo};
use crate::varname::VarName;

use super::{Model, TildeApi};

/// Draws missing variables from their priors into an [`UntypedVarInfo`].
///
/// - Variables already present (and not flagged `RESAMPLE`) keep their
///   stored value; their metadata (distribution) is refreshed since
///   parameters of the distribution may have changed.
/// - Missing or flagged variables are drawn fresh.
///
/// This executor is the paper's "initial sampling phase" and also serves
/// prior sampling and MH re-evaluation of boxed traces.
pub struct SampleExecutor<'a, R: RngCore> {
    rng: &'a mut R,
    vi: &'a mut UntypedVarInfo,
    acc: Accumulator<f64>,
    ctx: Context,
}

impl<'a, R: RngCore> SampleExecutor<'a, R> {
    pub fn new(rng: &'a mut R, vi: &'a mut UntypedVarInfo, ctx: Context) -> Self {
        Self {
            rng,
            vi,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }

    fn fetch_or_draw(&mut self, vn: VarName, dist: crate::dist::AnyDist) -> Value {
        if self.vi.contains(&vn) && !self.vi.is_flagged(&vn, flags::RESAMPLE) {
            let val = self.vi.get(&vn).unwrap().value.clone();
            self.vi.update(&vn, val.clone(), dist);
            val
        } else {
            let val = dist.sample(self.rng);
            if self.vi.contains(&vn) {
                self.vi.update(&vn, val.clone(), dist);
                self.vi.clear_flag(&vn, flags::RESAMPLE);
            } else {
                self.vi.insert(vn, val.clone(), dist);
            }
            val
        }
    }
}

impl<'a, R: RngCore> TildeApi<f64> for SampleExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let x = val.as_f64().expect("scalar assume got non-scalar value");
        self.acc.add_prior(dist.logpdf(x));
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let x = val
            .as_slice()
            .expect("vector assume got non-vector value")
            .to_vec();
        self.acc.add_prior(dist.logpdf(&x));
        x
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let k = val.as_int().expect("discrete assume got non-integer value");
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        self.acc.add_lik(dist.logpdf(obs));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        self.acc.add_lik(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        self.acc.add_lik(dist.logpdf(obs));
    }

    fn add_obs_logp(&mut self, lp: f64) {
        self.acc.add_lik(lp);
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}

/// Evaluates the log-density from a flat unconstrained slice using the
/// frozen [`TypedVarInfo`] layout — the specialized fast path.
///
/// Assumes are served by a cursor walk over the layout: slot `i` of the
/// layout must be visit `i` of the model (checked with `debug_assert`).
/// Each assume invlinks its coordinates (adding the Jacobian term) and
/// scores the prior. Generic over `T` so the same executor computes plain
/// values, forward duals and tape gradients. Invlinks write straight into
/// fixed-size destinations ([`bijector::invlink_slice`]); the only
/// allocation per assume is the `Vec` an `assume_vec` must hand back.
pub struct TypedExecutor<'a, T: Scalar> {
    tvi: &'a TypedVarInfo,
    theta: &'a [T],
    cursor: usize,
    acc: Accumulator<T>,
    ctx: Context,
}

impl<'a> TypedExecutor<'a, f64> {
    pub fn new(tvi: &'a TypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        Self::new_generic(tvi, theta, ctx)
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }
}

impl<'a, T: Scalar> TypedExecutor<'a, T> {
    pub fn new_generic(tvi: &'a TypedVarInfo, theta: &'a [T], ctx: Context) -> Self {
        debug_assert_eq!(theta.len(), tvi.dim());
        Self {
            tvi,
            theta,
            cursor: 0,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp_t(&self) -> T {
        self.acc.total()
    }

    #[inline]
    fn next_slot(&mut self, vn: &VarName) -> &'a crate::varinfo::Slot {
        let slot = self
            .tvi
            .slots()
            .get(self.cursor)
            .unwrap_or_else(|| panic!("typed layout exhausted at {vn} — dynamic structure change; re-specialize the trace"));
        debug_assert_eq!(
            &slot.vn, vn,
            "typed layout mismatch: expected {}, model visited {vn}",
            slot.vn
        );
        self.cursor += 1;
        slot
    }
}

impl<'a, T: Scalar> TildeApi<T> for TypedExecutor<'a, T> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T {
        let slot = self.next_slot(&vn);
        let y = &self.theta[slot.unc_offset..slot.unc_offset + slot.unc_len];
        let mut out = [T::constant(0.0)];
        let ladj = bijector::invlink_slice(&slot.domain, y, &mut out);
        self.acc.add_prior(dist.logpdf(out[0]) + ladj);
        out[0]
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T> {
        let slot = self.next_slot(&vn);
        let y = &self.theta[slot.unc_offset..slot.unc_offset + slot.unc_len];
        let mut out = vec![T::constant(0.0); slot.cons_len];
        let ladj = bijector::invlink_slice(&slot.domain, y, &mut out);
        self.acc.add_prior(dist.logpdf(&out) + ladj);
        out
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64 {
        let slot = self.next_slot(&vn);
        let k = self.tvi.discrete[slot.disc_offset];
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64) {
        self.acc.add_lik(dist.logpdf(T::constant(obs)));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64) {
        self.acc.add_lik(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]) {
        let obs_t: Vec<T> = obs.iter().map(|&o| T::constant(o)).collect();
        self.acc.add_lik(dist.logpdf(&obs_t));
    }

    fn add_obs_logp(&mut self, lp: T) {
        self.acc.add_lik(lp);
    }

    fn add_prior_logp(&mut self, lp: T) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}

/// What counts as a bootstrap proposal during a typed replay run — the
/// typed mirror of the boxed `ReplayExecutor`'s `scope` parameter, but
/// resolved per *slot index* (one bitmask lookup) instead of per
/// `VarName` subsumption test.
#[derive(Clone, Copy, Debug)]
pub enum ReplayScope<'a> {
    /// Plain SMC: every assume is a bootstrap proposal whose prior cancels
    /// in the importance weight.
    Unscoped,
    /// Conditional cloud (Particle-Gibbs): slot `i` is proposed iff
    /// `mask[i]`; out-of-scope assumes locked in by the current window
    /// contribute their prior term to the weight.
    Mask(&'a [bool]),
    /// Pure evaluation: nothing is proposed, so every in-window assume's
    /// prior is scored — `log p(future latents, future obs | prefix)`,
    /// the ancestor-sampling weight (and, under [`Context::Default`], the
    /// full constrained-space joint).
    Eval,
}

/// Outcome of one typed replay run.
#[derive(Clone, Copy, Debug)]
pub struct TypedReplayReport {
    /// Context-weighted accumulator total: the incremental log-weight
    /// under `Context::ObsWindow`, the full log-joint under
    /// `Context::Default`.
    pub delta_logw: f64,
    /// Total observe statements the model visited.
    pub obs_total: usize,
    /// `false` when the model's visit sequence diverged from the frozen
    /// layout (dynamic structure change): the run was aborted via
    /// rejection, the trace buffers are garbage, and the caller must
    /// restore a snapshot and fall back to the boxed path.
    pub layout_ok: bool,
}

/// The typed particle fast path: replay-with-regenerate as a **cursor walk
/// over forked [`TypedVarInfo`] buffers** — no hashing, no boxed values,
/// no `AnyDist` clones. Semantically identical to
/// [`crate::particle::ReplayExecutor`] (replay unflagged slots from the
/// flat buffers, draw flagged slots fresh via `dist.sample` + link into
/// both buffers, score only the `[lo, hi)` observation window, stamp the
/// scored prefix `LOCKED`), and bitwise-identical for a fixed RNG stream:
/// both executors read/write exactly the same `f64` values in the same
/// order, so log-evidence and particle values agree to the last bit.
///
/// The one thing the boxed executor can do that this one cannot is absorb
/// a *structure change* (a model visiting different variables than the
/// layout recorded). The cursor walk detects that — wrong name, wrong
/// domain shape, layout exhausted, or layout not fully consumed — and
/// reports `layout_ok = false` instead of panicking; the particle cloud
/// then demotes the sweep to the boxed path.
pub struct TypedReplayExecutor<'a, R: RngCore> {
    rng: &'a mut R,
    tvi: &'a mut TypedVarInfo,
    acc: Accumulator<f64>,
    ctx: Context,
    scope: ReplayScope<'a>,
    lo: usize,
    hi: usize,
    cursor: usize,
    obs_seen: usize,
    layout_ok: bool,
    locking_done: bool,
}

impl<'a, R: RngCore> TypedReplayExecutor<'a, R> {
    pub fn new(
        rng: &'a mut R,
        tvi: &'a mut TypedVarInfo,
        ctx: Context,
        scope: ReplayScope<'a>,
    ) -> Self {
        let (lo, hi) = ctx.obs_window();
        Self {
            rng,
            tvi,
            acc: Accumulator::new(ctx),
            ctx,
            scope,
            lo,
            hi,
            cursor: 0,
            obs_seen: 0,
            layout_ok: true,
            // hi = 0: nothing scored yet → nothing to lock; hi = MAX is a
            // non-particle context (full evaluation) → don't stamp locks.
            locking_done: hi == 0 || hi == usize::MAX,
        }
    }

    /// Run `model` once over `tvi` and report.
    pub fn run(
        model: &dyn Model,
        rng: &'a mut R,
        tvi: &'a mut TypedVarInfo,
        ctx: Context,
        scope: ReplayScope<'a>,
    ) -> TypedReplayReport {
        let mut exec = TypedReplayExecutor::new(rng, tvi, ctx, scope);
        model.eval_f64(&mut exec);
        exec.finalize()
    }

    fn finalize(mut self) -> TypedReplayReport {
        // A run that ended with slots left unvisited changed structure
        // (model shrank) — unless it was cut short by a genuine −∞
        // rejection, which the boxed path tolerates identically.
        if self.layout_ok && !self.acc.rejected() && self.cursor != self.tvi.slots().len() {
            self.layout_ok = false;
        }
        if self.layout_ok && !self.locking_done {
            // observe counter never reached `hi`: everything visited this
            // run was scored by the window — lock it (mirrors the boxed
            // executor's finalize).
            for i in 0..self.cursor {
                self.tvi.flag_slot(i, flags::LOCKED);
            }
        }
        TypedReplayReport {
            delta_logw: self.acc.total(),
            obs_total: self.obs_seen,
            layout_ok: self.layout_ok,
        }
    }

    /// Cursor step: the next slot must carry this variable with a
    /// structurally compatible domain. On divergence the run is poisoned
    /// (rejected + `layout_ok = false`) and every later tilde statement
    /// short-circuits to shape-correct dummies.
    #[inline]
    fn next_slot(&mut self, vn: &VarName, domain: &crate::dist::Domain) -> Option<usize> {
        if !self.layout_ok {
            return None;
        }
        let i = self.cursor;
        let ok = match self.tvi.slots().get(i) {
            Some(s) => s.vn == *vn && s.domain.compatible(domain),
            None => false,
        };
        if ok {
            self.cursor += 1;
            Some(i)
        } else {
            self.layout_ok = false;
            self.acc.reject();
            None
        }
    }

    /// Count an observe statement; true if it falls inside the window.
    /// Reaching the window end stamps every slot visited so far `LOCKED`
    /// (for a static layout, visit order *is* slot order, so the scored
    /// prefix is exactly `0..cursor`).
    #[inline]
    fn note_obs(&mut self) -> bool {
        let i = self.obs_seen;
        self.obs_seen += 1;
        if self.obs_seen == self.hi && !self.locking_done {
            for k in 0..self.cursor {
                self.tvi.flag_slot(k, flags::LOCKED);
            }
            self.locking_done = true;
        }
        i >= self.lo && i < self.hi
    }

    /// Score an assume's prior term — same rule as the boxed executor: an
    /// assume visited inside the window contributes to the weight iff it
    /// is *not* a proposal draw; everything else goes to the (possibly
    /// zero-weighted) prior side, which still triggers −∞ rejection.
    #[inline]
    fn score_assume(&mut self, si: usize, lp: f64) {
        let in_window = self.obs_seen >= self.lo && self.obs_seen < self.hi;
        let proposed = match self.scope {
            ReplayScope::Unscoped => true,
            ReplayScope::Mask(m) => m[si],
            ReplayScope::Eval => false,
        };
        if in_window && !proposed {
            self.acc.add_lik(lp);
        } else {
            self.acc.add_prior(lp);
        }
    }
}

impl<'a, R: RngCore> TildeApi<f64> for TypedReplayExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            None => return 0.0,
        };
        let x = if self.tvi.is_slot_flagged(si, flags::RESAMPLE) {
            let x = dist.sample(self.rng);
            self.tvi.write_slot_f64(si, x, &domain);
            self.tvi.clear_slot_flag(si, flags::RESAMPLE);
            x
        } else {
            self.tvi.constrained[self.tvi.slots()[si].cons_offset]
        };
        self.score_assume(si, dist.logpdf(x));
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            // shape-correct dummy: the (rejected) model body may index it
            None => return vec![0.0; domain.constrained_dim()],
        };
        let (co, cl) = {
            let s = &self.tvi.slots()[si];
            (s.cons_offset, s.cons_len)
        };
        let xs = if self.tvi.is_slot_flagged(si, flags::RESAMPLE) {
            let xs = dist.sample(self.rng);
            self.tvi.write_slot_vec(si, &xs, &domain);
            self.tvi.clear_slot_flag(si, flags::RESAMPLE);
            xs
        } else {
            self.tvi.constrained[co..co + cl].to_vec()
        };
        self.score_assume(si, dist.logpdf(&xs));
        xs
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            None => return 0,
        };
        let k = if self.tvi.is_slot_flagged(si, flags::RESAMPLE) {
            let k = dist.sample(self.rng);
            self.tvi.write_slot_int(si, k);
            self.tvi.clear_slot_flag(si, flags::RESAMPLE);
            k
        } else {
            self.tvi.discrete[self.tvi.slots()[si].disc_offset]
        };
        self.score_assume(si, dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpdf(obs));
        }
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpmf(obs));
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpdf(obs));
        }
    }

    fn add_obs_logp(&mut self, lp: f64) {
        if self.note_obs() {
            self.acc.add_lik(lp);
        }
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}

/// Evaluates the log-density from a flat unconstrained slice **through the
/// boxed trace**: every assume re-derives its offset by hashing the
/// `VarName` and re-reads domain metadata through the `AnyDist` enum.
///
/// Semantically identical to [`TypedExecutor`]; mechanically it pays the
/// dynamic costs the paper's §2.2 attributes to `UntypedVarInfo` (abstract
/// element types defeating specialization). Offsets are recomputed each
/// run from the record order, mimicking `Vector{Real}` re-traversal.
pub struct UntypedFlatExecutor<'a, T: Scalar> {
    vi: &'a UntypedVarInfo,
    offsets: std::collections::HashMap<VarName, usize>,
    theta: &'a [T],
    acc: Accumulator<T>,
    ctx: Context,
}

impl<'a> UntypedFlatExecutor<'a, f64> {
    pub fn new(vi: &'a UntypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        Self::new_generic(vi, theta, ctx)
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }
}

impl<'a, T: Scalar> UntypedFlatExecutor<'a, T> {
    pub fn new_generic(vi: &'a UntypedVarInfo, theta: &'a [T], ctx: Context) -> Self {
        // Rebuild the VarName→offset map on every executor construction —
        // the boxed path has no frozen layout to reuse.
        let mut offsets = std::collections::HashMap::new();
        let mut off = 0;
        for rec in vi.records() {
            offsets.insert(rec.vn.clone(), off);
            off += rec.domain.unconstrained_dim();
        }
        debug_assert_eq!(off, theta.len());
        Self {
            vi,
            offsets,
            theta,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp_t(&self) -> T {
        self.acc.total()
    }

    fn lookup(&self, vn: &VarName) -> (usize, crate::dist::Domain) {
        let off = *self
            .offsets
            .get(vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace — dynamic structure change"));
        let rec = self.vi.get(vn).unwrap();
        (off, rec.domain.clone())
    }
}

impl<'a, T: Scalar> TildeApi<T> for UntypedFlatExecutor<'a, T> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T {
        let (off, domain) = self.lookup(&vn);
        let n = domain.unconstrained_dim();
        let mut out = Vec::with_capacity(1);
        let ladj = bijector::invlink(&domain, &self.theta[off..off + n], &mut out);
        let x = out[0];
        self.acc.add_prior(dist.logpdf(x) + ladj);
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T> {
        let (off, domain) = self.lookup(&vn);
        let n = domain.unconstrained_dim();
        let mut out = Vec::with_capacity(domain.constrained_dim());
        let ladj = bijector::invlink(&domain, &self.theta[off..off + n], &mut out);
        self.acc.add_prior(dist.logpdf(&out) + ladj);
        out
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64 {
        let rec = self
            .vi
            .get(&vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace"));
        let k = rec.value.as_int().expect("discrete assume of non-integer");
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64) {
        self.acc.add_lik(dist.logpdf(T::constant(obs)));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64) {
        self.acc.add_lik(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]) {
        let obs_t: Vec<T> = obs.iter().map(|&o| T::constant(o)).collect();
        self.acc.add_lik(dist.logpdf(&obs_t));
    }

    fn add_obs_logp(&mut self, lp: T) {
        self.acc.add_lik(lp);
    }

    fn add_prior_logp(&mut self, lp: T) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }
}
