//! [`TildeApi`] implementations: the three ways a model body executes.

use rand_core::RngCore;

use crate::ad::arena::{self, AVar};
use crate::ad::Scalar;
use crate::context::{Accumulator, Context};
use crate::dist::{bijector, DiscreteDist, Domain, ScalarAdj, ScalarDist, VecDist};
use crate::obs::profile;
use crate::value::Value;
use crate::varinfo::{flags, TypedVarInfo, UntypedVarInfo};
use crate::varname::VarName;

use super::{Model, TildeApi};

/// Draws missing variables from their priors into an [`UntypedVarInfo`].
///
/// - Variables already present (and not flagged `RESAMPLE`) keep their
///   stored value; their metadata (distribution) is refreshed since
///   parameters of the distribution may have changed.
/// - Missing or flagged variables are drawn fresh.
///
/// This executor is the paper's "initial sampling phase" and also serves
/// prior sampling and MH re-evaluation of boxed traces.
pub struct SampleExecutor<'a, R: RngCore> {
    rng: &'a mut R,
    vi: &'a mut UntypedVarInfo,
    acc: Accumulator<f64>,
    ctx: Context,
}

impl<'a, R: RngCore> SampleExecutor<'a, R> {
    pub fn new(rng: &'a mut R, vi: &'a mut UntypedVarInfo, ctx: Context) -> Self {
        Self {
            rng,
            vi,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }

    fn fetch_or_draw(&mut self, vn: VarName, dist: crate::dist::AnyDist) -> Value {
        if self.vi.contains(&vn) && !self.vi.is_flagged(&vn, flags::RESAMPLE) {
            let val = self.vi.get(&vn).unwrap().value.clone();
            self.vi.update(&vn, val.clone(), dist);
            val
        } else {
            let val = dist.sample(self.rng);
            if self.vi.contains(&vn) {
                self.vi.update(&vn, val.clone(), dist);
                self.vi.clear_flag(&vn, flags::RESAMPLE);
            } else {
                self.vi.insert(vn, val.clone(), dist);
            }
            val
        }
    }
}

impl<'a, R: RngCore> TildeApi<f64> for SampleExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let x = val.as_f64().expect("scalar assume got non-scalar value");
        self.acc.add_prior(dist.logpdf(x));
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let x = val
            .as_slice()
            .expect("vector assume got non-vector value")
            .to_vec();
        self.acc.add_prior(dist.logpdf(&x));
        x
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        let val = self.fetch_or_draw(vn, dist.boxed());
        let k = val.as_int().expect("discrete assume got non-integer value");
        self.acc.add_prior(dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        self.acc.add_obs(dist.logpdf(obs));
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        self.acc.add_obs(dist.logpmf(obs));
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        self.acc.add_obs(dist.logpdf(obs));
    }

    fn add_obs_logp(&mut self, lp: f64) {
        self.acc.add_obs(lp);
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        self.acc.skip_obs(n);
    }
}

/// Cursor step shared by the typed flat executors: visit `i` of the model
/// must be slot `i` of the frozen layout (checked with `debug_assert`);
/// exhausting the layout is a dynamic structure change.
#[inline]
pub(crate) fn cursor_next_slot<'a>(
    tvi: &'a TypedVarInfo,
    cursor: &mut usize,
    vn: &VarName,
) -> &'a crate::varinfo::Slot {
    let slot = tvi.slots().get(*cursor).unwrap_or_else(|| {
        panic!("typed layout exhausted at {vn} — dynamic structure change; re-specialize the trace")
    });
    debug_assert_eq!(
        &slot.vn, vn,
        "typed layout mismatch: expected {}, model visited {vn}",
        slot.vn
    );
    *cursor += 1;
    slot
}

/// Rebuild a boxed trace's `VarName` → unconstrained-offset map (FNV-keyed
/// — see `util::hash`). The boxed path has no frozen layout to reuse, so
/// both untyped flat executors pay this on every construction, mimicking
/// `Vector{Real}` re-traversal.
fn untyped_offset_map(vi: &UntypedVarInfo) -> crate::util::hash::FnvHashMap<VarName, usize> {
    let mut offsets = crate::util::hash::FnvHashMap::default();
    let mut off = 0;
    for rec in vi.records() {
        offsets.insert(rec.vn.clone(), off);
        off += rec.domain.unconstrained_dim();
    }
    offsets
}

/// Evaluates the log-density from a flat unconstrained slice using the
/// frozen [`TypedVarInfo`] layout — the specialized fast path.
///
/// Assumes are served by a cursor walk over the layout: slot `i` of the
/// layout must be visit `i` of the model (checked with `debug_assert`).
/// Each assume invlinks its coordinates (adding the Jacobian term) and
/// scores the prior. Generic over `T` so the same executor computes plain
/// values, forward duals and tape gradients. Invlinks write straight into
/// fixed-size destinations ([`bijector::invlink_slice`]); the only
/// allocation per assume is the `Vec` an `assume_vec` must hand back.
pub struct TypedExecutor<'a, T: Scalar> {
    tvi: &'a TypedVarInfo,
    theta: &'a [T],
    cursor: usize,
    acc: Accumulator<T>,
    ctx: Context,
}

impl<'a> TypedExecutor<'a, f64> {
    pub fn new(tvi: &'a TypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        Self::new_generic(tvi, theta, ctx)
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }
}

impl<'a, T: Scalar> TypedExecutor<'a, T> {
    pub fn new_generic(tvi: &'a TypedVarInfo, theta: &'a [T], ctx: Context) -> Self {
        debug_assert_eq!(theta.len(), tvi.dim());
        Self {
            tvi,
            theta,
            cursor: 0,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp_t(&self) -> T {
        self.acc.total()
    }

    /// Observation sites counted this run (visited or skipped) — the `N`
    /// a `Context::Subsample` window indexes into.
    pub fn obs_count(&self) -> usize {
        self.acc.obs_seen()
    }

    #[inline]
    fn next_slot(&mut self, vn: &VarName) -> &'a crate::varinfo::Slot {
        cursor_next_slot(self.tvi, &mut self.cursor, vn)
    }
}

impl<'a, T: Scalar> TildeApi<T> for TypedExecutor<'a, T> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T {
        let prof = profile::begin(self.ctx);
        let slot = self.next_slot(&vn);
        let y = &self.theta[slot.unc_offset..slot.unc_offset + slot.unc_len];
        let mut out = [T::constant(0.0)];
        let ladj = bijector::invlink_slice(&slot.domain, y, &mut out);
        let lp = dist.logpdf(out[0]) + ladj;
        self.acc.add_prior(lp);
        profile::end_assume(prof, &vn, lp.value(), self.acc.rejected());
        out[0]
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T> {
        let prof = profile::begin(self.ctx);
        let slot = self.next_slot(&vn);
        let y = &self.theta[slot.unc_offset..slot.unc_offset + slot.unc_len];
        let mut out = vec![T::constant(0.0); slot.cons_len];
        let ladj = bijector::invlink_slice(&slot.domain, y, &mut out);
        let lp = dist.logpdf(&out) + ladj;
        self.acc.add_prior(lp);
        profile::end_assume(prof, &vn, lp.value(), self.acc.rejected());
        out
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64 {
        let prof = profile::begin(self.ctx);
        let slot = self.next_slot(&vn);
        let k = self.tvi.discrete[slot.disc_offset];
        let lp = dist.logpmf(k);
        self.acc.add_prior(lp);
        profile::end_assume(prof, &vn, lp.value(), self.acc.rejected());
        k
    }

    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64) {
        let prof = profile::begin(self.ctx);
        // window first: out-of-window sites skip the density evaluation
        if self.acc.note_obs() != 0.0 {
            let lp = dist.logpdf(T::constant(obs));
            self.acc.add_lik(lp);
            profile::end_observe(prof, lp.value(), self.acc.rejected());
        }
    }

    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64) {
        let prof = profile::begin(self.ctx);
        if self.acc.note_obs() != 0.0 {
            let lp = dist.logpmf(obs);
            self.acc.add_lik(lp);
            profile::end_observe(prof, lp.value(), self.acc.rejected());
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]) {
        let prof = profile::begin(self.ctx);
        if self.acc.note_obs() != 0.0 {
            let obs_t: Vec<T> = obs.iter().map(|&o| T::constant(o)).collect();
            let lp = dist.logpdf(&obs_t);
            self.acc.add_lik(lp);
            profile::end_observe(prof, lp.value(), self.acc.rejected());
        }
    }

    fn add_obs_logp(&mut self, lp: T) {
        self.acc.add_obs(lp);
    }

    fn add_prior_logp(&mut self, lp: T) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        self.acc.skip_obs(n);
    }
}

/// What counts as a bootstrap proposal during a typed replay run — the
/// typed mirror of the boxed `ReplayExecutor`'s `scope` parameter, but
/// resolved per *slot index* (one bitmask lookup) instead of per
/// `VarName` subsumption test.
#[derive(Clone, Copy, Debug)]
pub enum ReplayScope<'a> {
    /// Plain SMC: every assume is a bootstrap proposal whose prior cancels
    /// in the importance weight.
    Unscoped,
    /// Conditional cloud (Particle-Gibbs): slot `i` is proposed iff
    /// `mask[i]`; out-of-scope assumes locked in by the current window
    /// contribute their prior term to the weight.
    Mask(&'a [bool]),
    /// Pure evaluation: nothing is proposed, so every in-window assume's
    /// prior is scored — `log p(future latents, future obs | prefix)`,
    /// the ancestor-sampling weight (and, under [`Context::Default`], the
    /// full constrained-space joint).
    Eval,
}

/// Outcome of one typed replay run.
#[derive(Clone, Copy, Debug)]
pub struct TypedReplayReport {
    /// Context-weighted accumulator total: the incremental log-weight
    /// under `Context::ObsWindow`, the full log-joint under
    /// `Context::Default`.
    pub delta_logw: f64,
    /// Total observe statements the model visited.
    pub obs_total: usize,
    /// `false` when the model's visit sequence diverged from the frozen
    /// layout (dynamic structure change): the run was aborted via
    /// rejection, the trace buffers are garbage, and the caller must
    /// restore a snapshot and fall back to the boxed path.
    pub layout_ok: bool,
}

/// The typed particle fast path: replay-with-regenerate as a **cursor walk
/// over forked [`TypedVarInfo`] buffers** — no hashing, no boxed values,
/// no `AnyDist` clones. Semantically identical to
/// [`crate::particle::ReplayExecutor`] (replay unflagged slots from the
/// flat buffers, draw flagged slots fresh via `dist.sample` + link into
/// both buffers, score only the `[lo, hi)` observation window, stamp the
/// scored prefix `LOCKED`), and bitwise-identical for a fixed RNG stream:
/// both executors read/write exactly the same `f64` values in the same
/// order, so log-evidence and particle values agree to the last bit.
///
/// The one thing the boxed executor can do that this one cannot is absorb
/// a *structure change* (a model visiting different variables than the
/// layout recorded). The cursor walk detects that — wrong name, wrong
/// domain shape, layout exhausted, or layout not fully consumed — and
/// reports `layout_ok = false` instead of panicking; the particle cloud
/// then demotes the sweep to the boxed path.
pub struct TypedReplayExecutor<'a, R: RngCore> {
    rng: &'a mut R,
    tvi: &'a mut TypedVarInfo,
    acc: Accumulator<f64>,
    ctx: Context,
    scope: ReplayScope<'a>,
    lo: usize,
    hi: usize,
    cursor: usize,
    obs_seen: usize,
    layout_ok: bool,
    locking_done: bool,
}

impl<'a, R: RngCore> TypedReplayExecutor<'a, R> {
    pub fn new(
        rng: &'a mut R,
        tvi: &'a mut TypedVarInfo,
        ctx: Context,
        scope: ReplayScope<'a>,
    ) -> Self {
        let (lo, hi) = ctx.obs_window();
        Self {
            rng,
            tvi,
            acc: Accumulator::new(ctx),
            ctx,
            scope,
            lo,
            hi,
            cursor: 0,
            obs_seen: 0,
            layout_ok: true,
            // hi = 0: nothing scored yet → nothing to lock; hi = MAX is a
            // non-particle context (full evaluation) → don't stamp locks.
            locking_done: hi == 0 || hi == usize::MAX,
        }
    }

    /// Run `model` once over `tvi` and report.
    pub fn run(
        model: &dyn Model,
        rng: &'a mut R,
        tvi: &'a mut TypedVarInfo,
        ctx: Context,
        scope: ReplayScope<'a>,
    ) -> TypedReplayReport {
        let mut exec = TypedReplayExecutor::new(rng, tvi, ctx, scope);
        model.eval_f64(&mut exec);
        exec.finalize()
    }

    fn finalize(mut self) -> TypedReplayReport {
        // A run that ended with slots left unvisited changed structure
        // (model shrank) — unless it was cut short by a genuine −∞
        // rejection, which the boxed path tolerates identically.
        if self.layout_ok && !self.acc.rejected() && self.cursor != self.tvi.slots().len() {
            self.layout_ok = false;
        }
        if self.layout_ok && !self.locking_done {
            // observe counter never reached `hi`: everything visited this
            // run was scored by the window — lock it (mirrors the boxed
            // executor's finalize).
            for i in 0..self.cursor {
                self.tvi.flag_slot(i, flags::LOCKED);
            }
        }
        TypedReplayReport {
            delta_logw: self.acc.total(),
            obs_total: self.obs_seen,
            layout_ok: self.layout_ok,
        }
    }

    /// Cursor step: the next slot must carry this variable with a
    /// structurally compatible domain. On divergence the run is poisoned
    /// (rejected + `layout_ok = false`) and every later tilde statement
    /// short-circuits to shape-correct dummies.
    #[inline]
    fn next_slot(&mut self, vn: &VarName, domain: &crate::dist::Domain) -> Option<usize> {
        if !self.layout_ok {
            return None;
        }
        let i = self.cursor;
        let ok = match self.tvi.slots().get(i) {
            Some(s) => s.vn == *vn && s.domain.compatible(domain),
            None => false,
        };
        if ok {
            self.cursor += 1;
            Some(i)
        } else {
            self.layout_ok = false;
            self.acc.reject();
            None
        }
    }

    /// Count an observe statement; true if it falls inside the window.
    /// Reaching the window end stamps every slot visited so far `LOCKED`
    /// (for a static layout, visit order *is* slot order, so the scored
    /// prefix is exactly `0..cursor`).
    #[inline]
    fn note_obs(&mut self) -> bool {
        let i = self.obs_seen;
        self.obs_seen += 1;
        if self.obs_seen == self.hi && !self.locking_done {
            for k in 0..self.cursor {
                self.tvi.flag_slot(k, flags::LOCKED);
            }
            self.locking_done = true;
        }
        i >= self.lo && i < self.hi
    }

    /// Score an assume's prior term — same rule as the boxed executor: an
    /// assume visited inside the window contributes to the weight iff it
    /// is *not* a proposal draw; everything else goes to the (possibly
    /// zero-weighted) prior side, which still triggers −∞ rejection.
    #[inline]
    fn score_assume(&mut self, si: usize, lp: f64) {
        let in_window = self.obs_seen >= self.lo && self.obs_seen < self.hi;
        let proposed = match self.scope {
            ReplayScope::Unscoped => true,
            ReplayScope::Mask(m) => m[si],
            ReplayScope::Eval => false,
        };
        if in_window && !proposed {
            self.acc.add_lik(lp);
        } else {
            self.acc.add_prior(lp);
        }
    }
}

impl<'a, R: RngCore> TildeApi<f64> for TypedReplayExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<f64>) -> f64 {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            None => return 0.0,
        };
        let x = if self.tvi.is_slot_flagged(si, flags::RESAMPLE) {
            let x = dist.sample(self.rng);
            self.tvi.write_slot_f64(si, x, &domain);
            self.tvi.clear_slot_flag(si, flags::RESAMPLE);
            x
        } else {
            self.tvi.constrained[self.tvi.slots()[si].cons_offset]
        };
        self.score_assume(si, dist.logpdf(x));
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<f64>) -> Vec<f64> {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            // shape-correct dummy: the (rejected) model body may index it
            None => return vec![0.0; domain.constrained_dim()],
        };
        let (co, cl) = {
            let s = &self.tvi.slots()[si];
            (s.cons_offset, s.cons_len)
        };
        let xs = if self.tvi.is_slot_flagged(si, flags::RESAMPLE) {
            let xs = dist.sample(self.rng);
            self.tvi.write_slot_vec(si, &xs, &domain);
            self.tvi.clear_slot_flag(si, flags::RESAMPLE);
            xs
        } else {
            self.tvi.constrained[co..co + cl].to_vec()
        };
        self.score_assume(si, dist.logpdf(&xs));
        xs
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<f64>) -> i64 {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            None => return 0,
        };
        let k = if self.tvi.is_slot_flagged(si, flags::RESAMPLE) {
            let k = dist.sample(self.rng);
            self.tvi.write_slot_int(si, k);
            self.tvi.clear_slot_flag(si, flags::RESAMPLE);
            k
        } else {
            self.tvi.discrete[self.tvi.slots()[si].disc_offset]
        };
        self.score_assume(si, dist.logpmf(k));
        k
    }

    fn observe(&mut self, dist: &ScalarDist<f64>, obs: f64) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpdf(obs));
        }
    }

    fn observe_int(&mut self, dist: &DiscreteDist<f64>, obs: i64) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpmf(obs));
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<f64>, obs: &[f64]) {
        if self.note_obs() {
            self.acc.add_lik(dist.logpdf(obs));
        }
    }

    fn add_obs_logp(&mut self, lp: f64) {
        if self.note_obs() {
            self.acc.add_lik(lp);
        }
    }

    fn add_prior_logp(&mut self, lp: f64) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        // advance through note_obs so crossing the window end still stamps
        // the scored prefix LOCKED
        for _ in 0..n {
            let _ = self.note_obs();
        }
    }
}

/// Evaluates the log-density from a flat unconstrained slice **through the
/// boxed trace**: every assume re-derives its offset by hashing the
/// `VarName` and re-reads domain metadata through the `AnyDist` enum.
///
/// Semantically identical to [`TypedExecutor`]; mechanically it pays the
/// dynamic costs the paper's §2.2 attributes to `UntypedVarInfo` (abstract
/// element types defeating specialization). Offsets are recomputed each
/// run from the record order, mimicking `Vector{Real}` re-traversal.
pub struct UntypedFlatExecutor<'a, T: Scalar> {
    vi: &'a UntypedVarInfo,
    offsets: crate::util::hash::FnvHashMap<VarName, usize>,
    theta: &'a [T],
    acc: Accumulator<T>,
    ctx: Context,
}

impl<'a> UntypedFlatExecutor<'a, f64> {
    pub fn new(vi: &'a UntypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        Self::new_generic(vi, theta, ctx)
    }

    pub fn logp(&self) -> f64 {
        self.acc.total()
    }
}

impl<'a, T: Scalar> UntypedFlatExecutor<'a, T> {
    pub fn new_generic(vi: &'a UntypedVarInfo, theta: &'a [T], ctx: Context) -> Self {
        debug_assert_eq!(vi.num_unconstrained(), theta.len());
        Self {
            vi,
            offsets: untyped_offset_map(vi),
            theta,
            acc: Accumulator::new(ctx),
            ctx,
        }
    }

    pub fn logp_t(&self) -> T {
        self.acc.total()
    }

    fn lookup(&self, vn: &VarName) -> (usize, crate::dist::Domain) {
        let off = *self
            .offsets
            .get(vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace — dynamic structure change"));
        let rec = self.vi.get(vn).unwrap();
        (off, rec.domain.clone())
    }
}

impl<'a, T: Scalar> TildeApi<T> for UntypedFlatExecutor<'a, T> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T {
        let prof = profile::begin(self.ctx);
        let (off, domain) = self.lookup(&vn);
        let n = domain.unconstrained_dim();
        let mut out = Vec::with_capacity(1);
        let ladj = bijector::invlink(&domain, &self.theta[off..off + n], &mut out);
        let x = out[0];
        let lp = dist.logpdf(x) + ladj;
        self.acc.add_prior(lp);
        profile::end_assume(prof, &vn, lp.value(), self.acc.rejected());
        x
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T> {
        let prof = profile::begin(self.ctx);
        let (off, domain) = self.lookup(&vn);
        let n = domain.unconstrained_dim();
        let mut out = Vec::with_capacity(domain.constrained_dim());
        let ladj = bijector::invlink(&domain, &self.theta[off..off + n], &mut out);
        let lp = dist.logpdf(&out) + ladj;
        self.acc.add_prior(lp);
        profile::end_assume(prof, &vn, lp.value(), self.acc.rejected());
        out
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64 {
        let prof = profile::begin(self.ctx);
        let rec = self
            .vi
            .get(&vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace"));
        let k = rec.value.as_int().expect("discrete assume of non-integer");
        let lp = dist.logpmf(k);
        self.acc.add_prior(lp);
        profile::end_assume(prof, &vn, lp.value(), self.acc.rejected());
        k
    }

    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64) {
        let prof = profile::begin(self.ctx);
        if self.acc.note_obs() != 0.0 {
            let lp = dist.logpdf(T::constant(obs));
            self.acc.add_lik(lp);
            profile::end_observe(prof, lp.value(), self.acc.rejected());
        }
    }

    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64) {
        let prof = profile::begin(self.ctx);
        if self.acc.note_obs() != 0.0 {
            let lp = dist.logpmf(obs);
            self.acc.add_lik(lp);
            profile::end_observe(prof, lp.value(), self.acc.rejected());
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]) {
        let prof = profile::begin(self.ctx);
        if self.acc.note_obs() != 0.0 {
            let obs_t: Vec<T> = obs.iter().map(|&o| T::constant(o)).collect();
            let lp = dist.logpdf(&obs_t);
            self.acc.add_lik(lp);
            profile::end_observe(prof, lp.value(), self.acc.rejected());
        }
    }

    fn add_obs_logp(&mut self, lp: T) {
        self.acc.add_obs(lp);
    }

    fn add_prior_logp(&mut self, lp: T) {
        self.acc.add_prior(lp);
    }

    fn reject(&mut self) {
        self.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.acc.rejected()
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        self.acc.skip_obs(n);
    }
}

// ------------------------------------------------------------- fused path

/// Reused buffers for the fused executors, parked in a thread-local
/// between gradient evaluations so the steady-state `logp_grad_into` path
/// allocates nothing.
#[derive(Default)]
pub(crate) struct FusedScratch {
    /// Per-component ∂logpdf/∂x of the current vector statement.
    pub(crate) dx: Vec<f64>,
    /// Constrained primal values of the current vector statement.
    pub(crate) xs: Vec<f64>,
    /// Unconstrained coordinates as arena variables (simplex invlink).
    yv: Vec<AVar>,
}

thread_local! {
    static FUSED_SCRATCH: std::cell::RefCell<FusedScratch> =
        std::cell::RefCell::new(FusedScratch::default());
}

pub(crate) fn take_fused_scratch() -> FusedScratch {
    FUSED_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut()))
}

pub(crate) fn park_fused_scratch(scratch: FusedScratch) {
    FUSED_SCRATCH.with(|s| *s.borrow_mut() = scratch);
}

/// One fused scalar assume: invlink the single coordinate analytically,
/// evaluate the density's analytic adjoint, and attach the constrained
/// value to the tape as **at most one** node (`Real` aliases the input
/// leaf outright).
pub(crate) fn fused_assume_scalar(
    theta: &[f64],
    off: usize,
    domain: &Domain,
    dist: &ScalarDist<AVar>,
) -> (AVar, f64, ScalarAdj, bijector::ScalarLink) {
    let link = bijector::invlink_scalar_adj(domain, theta[off]);
    let adj = dist.logpdf_adj(link.x);
    let x = if matches!(domain, Domain::Real) {
        AVar::leaf(off as u32, link.x)
    } else {
        let idx = arena::with_tape(|t| t.push1(off as u32, link.dx_dy));
        AVar::from_node(idx, link.x)
    };
    (x, adj.lp + link.ladj, adj, link)
}

/// Seed the gradient contributions of a fused scalar assume, scaled by the
/// context's prior weight.
pub(crate) fn seed_assume_scalar(
    x: &AVar,
    off: usize,
    dist: &ScalarDist<AVar>,
    adj: &ScalarAdj,
    link: &bijector::ScalarLink,
    w: f64,
) {
    arena::with_tape(|t| {
        t.seed(x.idx(), adj.d_x * w);
        t.seed(off as u32, link.dladj_dy * w);
        let (ps, np) = dist.param_vars();
        for (p, d) in ps.iter().zip(adj.d_p).take(np) {
            t.seed(p.idx(), d * w);
        }
    });
}

/// One fused vector assume. Diagonal links (`RealVec`, `PositiveVec`) get
/// analytic per-component nodes (identity aliases the leaves, so costs
/// zero nodes); `Simplex` runs the generic stick-breaking invlink over
/// arena variables (O(n) two-parent nodes) and seeds the returned ladj
/// node. The density itself is always one analytic `logpdf_adj` kernel.
/// Returns `(value, lp, param partials, ladj node — NONE-indexed when the
/// ladj gradient is seeded directly on the leaves)`.
pub(crate) fn fused_assume_vec(
    theta: &[f64],
    off: usize,
    domain: &Domain,
    dist: &VecDist<AVar>,
    scratch: &mut FusedScratch,
) -> (Vec<AVar>, f64, ScalarAdj, AVar) {
    let n = domain.constrained_dim();
    scratch.dx.clear();
    scratch.dx.resize(n, 0.0);
    match domain {
        Domain::RealVec(_) => {
            let out: Vec<AVar> = (0..n)
                .map(|i| AVar::leaf((off + i) as u32, theta[off + i]))
                .collect();
            let adj = dist.logpdf_adj(&theta[off..off + n], &mut scratch.dx);
            (out, adj.lp, adj, AVar::constant(0.0))
        }
        Domain::PositiveVec(_) => {
            scratch.xs.clear();
            let mut ladj = 0.0;
            let out: Vec<AVar> = (0..n)
                .map(|i| {
                    let y = theta[off + i];
                    let x = y.exp();
                    ladj += y;
                    scratch.xs.push(x);
                    let idx = arena::with_tape(|t| t.push1((off + i) as u32, x));
                    AVar::from_node(idx, x)
                })
                .collect();
            let adj = dist.logpdf_adj(&scratch.xs, &mut scratch.dx);
            (out, adj.lp + ladj, adj, AVar::constant(0.0))
        }
        Domain::Simplex(_) => {
            let m = domain.unconstrained_dim();
            scratch.yv.clear();
            scratch
                .yv
                .extend((0..m).map(|i| AVar::leaf((off + i) as u32, theta[off + i])));
            let mut out = vec![AVar::constant(0.0); n];
            let ladj = bijector::invlink_slice(domain, &scratch.yv, &mut out);
            scratch.xs.clear();
            scratch.xs.extend(out.iter().map(|x| x.value()));
            let adj = dist.logpdf_adj(&scratch.xs, &mut scratch.dx);
            (out, adj.lp + ladj.value(), adj, ladj)
        }
        other => panic!("vector assume over scalar/discrete domain {other:?}"),
    }
}

/// Seed a fused vector assume: per-component density partials on the value
/// nodes, ladj partials on the leaves (diagonal links) or the ladj node
/// (simplex), parameter partials on the parameter variables.
#[allow(clippy::too_many_arguments)]
pub(crate) fn seed_assume_vec(
    out: &[AVar],
    off: usize,
    domain: &Domain,
    ladj: &AVar,
    dist: &VecDist<AVar>,
    adj: &ScalarAdj,
    dx: &[f64],
    w: f64,
) {
    arena::with_tape(|t| {
        for (x, &d) in out.iter().zip(dx) {
            t.seed(x.idx(), d * w);
        }
        match domain {
            Domain::PositiveVec(n) => {
                for i in 0..*n {
                    t.seed((off + i) as u32, w);
                }
            }
            Domain::Simplex(_) => t.seed(ladj.idx(), w),
            _ => {}
        }
        let (ps, np) = dist.param_vars();
        for (p, d) in ps.iter().zip(adj.d_p).take(np) {
            t.seed(p.idx(), d * w);
        }
    });
}

/// The engine shared by both fused executors: context-weighted
/// accumulation, seed-weight bookkeeping, the per-statement fused kernels
/// and the parked scratch. The two executor types differ only in how a
/// tilde statement resolves to an `(offset, domain)` — cursor walk over
/// the frozen layout vs hash lookup in the boxed trace.
///
/// Observation windowing (`Context::Subsample`/`ObsWindow`) is resolved
/// **before** the density kernel runs: an out-of-window observe costs no
/// `logpdf_adj` evaluation, no arena nodes and no seeds — which is what
/// makes minibatched evaluation of a tall likelihood O(batch), not O(N).
struct FusedCore {
    acc: Accumulator<f64>,
    ctx: Context,
    prior_w: f64,
    stmts: usize,
    scratch: FusedScratch,
}

impl FusedCore {
    fn new(ctx: Context) -> Self {
        Self {
            acc: Accumulator::new(ctx),
            ctx,
            prior_w: ctx.prior_weight(),
            stmts: 0,
            scratch: take_fused_scratch(),
        }
    }

    /// Final log-density + tilde-statement count; parks the scratch
    /// buffers for the next run.
    fn finish(self) -> (f64, usize) {
        let lp = self.acc.total();
        let stmts = self.stmts;
        park_fused_scratch(self.scratch);
        (lp, stmts)
    }

    /// Accumulate a prior-side term; returns the weight its seeds carry
    /// (0.0 when the term is dropped — context weight zero, or the run
    /// was already/just rejected).
    #[inline]
    fn prior_seed_weight(&mut self, lp: f64) -> f64 {
        let pre = self.acc.rejected();
        self.acc.add_prior(lp);
        if !pre && !self.acc.rejected() {
            self.prior_w
        } else {
            0.0
        }
    }

    /// Accumulate a likelihood-side term at the window-resolved weight
    /// `w` (from [`Accumulator::note_obs`]); returns the weight its seeds
    /// carry (0.0 when the run was already/just rejected).
    #[inline]
    fn lik_seed_weight(&mut self, lp: f64, w: f64) -> f64 {
        let pre = self.acc.rejected();
        self.acc.add_lik_weighted(lp, w);
        if !pre && !self.acc.rejected() {
            w
        } else {
            0.0
        }
    }

    fn assume_scalar(
        &mut self,
        theta: &[f64],
        off: usize,
        domain: &Domain,
        dist: &ScalarDist<AVar>,
        vn: &VarName,
    ) -> AVar {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let (x, lp, adj, link) = fused_assume_scalar(theta, off, domain, dist);
        let w = self.prior_seed_weight(lp);
        if w != 0.0 {
            seed_assume_scalar(&x, off, dist, &adj, &link, w);
        }
        profile::end_assume(prof, vn, lp, self.acc.rejected());
        x
    }

    fn assume_vec(
        &mut self,
        theta: &[f64],
        off: usize,
        domain: &Domain,
        dist: &VecDist<AVar>,
        vn: &VarName,
    ) -> Vec<AVar> {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let (out, lp, adj, ladj) = fused_assume_vec(theta, off, domain, dist, &mut self.scratch);
        let w = self.prior_seed_weight(lp);
        if w != 0.0 {
            seed_assume_vec(&out, off, domain, &ladj, dist, &adj, &self.scratch.dx, w);
        }
        profile::end_assume(prof, vn, lp, self.acc.rejected());
        out
    }

    /// [`Self::assume_scalar`] with the site's own value held fixed — the
    /// Gibbs out-of-block path. Identical lp arithmetic (the returned
    /// total stays bitwise equal to the unmasked walk), but the
    /// constrained value enters the tape as a constant: no invlink node,
    /// no `d_x`/`dladj` seeds, and any glue downstream of the value
    /// constant-collapses — the site costs zero arena nodes. Parameter
    /// partials are still seeded: an out-of-block density may depend on
    /// in-block variables through its parameters.
    fn assume_scalar_masked(
        &mut self,
        theta: &[f64],
        off: usize,
        domain: &Domain,
        dist: &ScalarDist<AVar>,
        vn: &VarName,
    ) -> AVar {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let link = bijector::invlink_scalar_adj(domain, theta[off]);
        let adj = dist.logpdf_adj(link.x);
        let lp = adj.lp + link.ladj;
        let w = self.prior_seed_weight(lp);
        if w != 0.0 {
            seed_params_scalar(dist, &adj, w);
        }
        profile::end_assume(prof, vn, lp, self.acc.rejected());
        AVar::constant(link.x)
    }

    /// [`Self::assume_vec`] with the site held fixed (Gibbs out-of-block):
    /// same per-component invlink/ladj arithmetic as the tracked path, but
    /// run on plain `f64` and returned as constants — zero arena nodes.
    /// Parameter partials are still seeded.
    fn assume_vec_masked(
        &mut self,
        theta: &[f64],
        off: usize,
        domain: &Domain,
        dist: &VecDist<AVar>,
        vn: &VarName,
    ) -> Vec<AVar> {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let n = domain.constrained_dim();
        self.scratch.dx.clear();
        self.scratch.dx.resize(n, 0.0);
        self.scratch.xs.clear();
        let (lp, adj) = match domain {
            Domain::RealVec(_) => {
                self.scratch.xs.extend_from_slice(&theta[off..off + n]);
                let adj = dist.logpdf_adj(&self.scratch.xs, &mut self.scratch.dx);
                (adj.lp, adj)
            }
            Domain::PositiveVec(_) => {
                let mut ladj = 0.0;
                for i in 0..n {
                    let y = theta[off + i];
                    ladj += y;
                    self.scratch.xs.push(y.exp());
                }
                let adj = dist.logpdf_adj(&self.scratch.xs, &mut self.scratch.dx);
                (adj.lp + ladj, adj)
            }
            Domain::Simplex(_) => {
                let m = domain.unconstrained_dim();
                self.scratch.xs.resize(n, 0.0);
                let ladj =
                    bijector::invlink_slice(domain, &theta[off..off + m], &mut self.scratch.xs);
                let adj = dist.logpdf_adj(&self.scratch.xs, &mut self.scratch.dx);
                (adj.lp + ladj, adj)
            }
            other => panic!("vector assume over scalar/discrete domain {other:?}"),
        };
        let w = self.prior_seed_weight(lp);
        if w != 0.0 {
            let (ps, np) = dist.param_vars();
            arena::with_tape(|t| {
                for (p, d) in ps.iter().zip(adj.d_p).take(np) {
                    t.seed(p.idx(), d * w);
                }
            });
        }
        profile::end_assume(prof, vn, lp, self.acc.rejected());
        self.scratch.xs.iter().map(|&x| AVar::constant(x)).collect()
    }

    /// Score a discrete assume whose value `k` the caller fetched from
    /// its trace representation.
    fn assume_int(&mut self, k: i64, dist: &DiscreteDist<AVar>, vn: &VarName) -> i64 {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let (lp, dp) = dist.logpmf_adj(k);
        let w = self.prior_seed_weight(lp);
        if w != 0.0 {
            if let Some(p) = dist.param_var() {
                arena::seed(p.idx(), dp * w);
            }
        }
        profile::end_assume(prof, vn, lp, self.acc.rejected());
        k
    }

    fn observe(&mut self, dist: &ScalarDist<AVar>, obs: f64) {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let cw = self.acc.note_obs();
        if cw == 0.0 {
            return; // out-of-window / zero-weight: no kernel, no seeds
        }
        let adj = dist.logpdf_adj(obs);
        let w = self.lik_seed_weight(adj.lp, cw);
        if w != 0.0 {
            seed_params_scalar(dist, &adj, w);
        }
        profile::end_observe(prof, adj.lp, self.acc.rejected());
    }

    fn observe_int(&mut self, dist: &DiscreteDist<AVar>, obs: i64) {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let cw = self.acc.note_obs();
        if cw == 0.0 {
            return;
        }
        let (lp, dp) = dist.logpmf_adj(obs);
        let w = self.lik_seed_weight(lp, cw);
        if w != 0.0 {
            if let Some(p) = dist.param_var() {
                arena::seed(p.idx(), dp * w);
            }
        }
        profile::end_observe(prof, lp, self.acc.rejected());
    }

    fn observe_vec(&mut self, dist: &VecDist<AVar>, obs: &[f64]) {
        self.stmts += 1;
        let prof = profile::begin(self.ctx);
        let cw = self.acc.note_obs();
        if cw == 0.0 {
            return;
        }
        self.scratch.dx.clear();
        self.scratch.dx.resize(obs.len(), 0.0);
        let adj = dist.logpdf_adj(obs, &mut self.scratch.dx);
        let w = self.lik_seed_weight(adj.lp, cw);
        if w != 0.0 {
            let (ps, np) = dist.param_vars();
            arena::with_tape(|t| {
                for (p, d) in ps.iter().zip(adj.d_p).take(np) {
                    t.seed(p.idx(), d * w);
                }
            });
        }
        profile::end_observe(prof, adj.lp, self.acc.rejected());
    }

    fn add_obs_logp(&mut self, lp: AVar) {
        self.stmts += 1;
        let cw = self.acc.note_obs();
        if cw == 0.0 {
            return;
        }
        let w = self.lik_seed_weight(lp.value(), cw);
        if w != 0.0 {
            arena::seed(lp.idx(), w);
        }
    }

    fn add_prior_logp(&mut self, lp: AVar) {
        self.stmts += 1;
        let w = self.prior_seed_weight(lp.value());
        arena::seed(lp.idx(), w);
    }
}

/// Seed a scalar density's parameter partials (observe statements).
pub(crate) fn seed_params_scalar(dist: &ScalarDist<AVar>, adj: &ScalarAdj, w: f64) {
    let (ps, np) = dist.param_vars();
    arena::with_tape(|t| {
        for (p, d) in ps.iter().zip(adj.d_p).take(np) {
            t.seed(p.idx(), d * w);
        }
    });
}

/// Evaluates log-density and **analytic-adjoint gradient seeds** from a
/// flat unconstrained slice over the frozen [`TypedVarInfo`] layout — the
/// arena-fused fast path ([`crate::gradient::Backend::ReverseFused`]).
///
/// Cursor semantics are identical to [`TypedExecutor`]; the difference is
/// what lands on the tape. Where the generic tape records ~20 scalar-op
/// nodes per tilde statement, this executor calls each distribution's
/// fused `logpdf_adj` kernel (value + closed-form partials in one pass)
/// and records the partials as *seeds*, so a tilde costs at most one value
/// node (`Real`-domain assumes and all observe statements cost zero).
/// Model-body arithmetic between tilde statements still traces through
/// [`AVar`] ops, which is what keeps arbitrary parameter dependencies
/// (`Normal(mu + phi * h, sigma)`) exact.
pub struct TypedFusedExecutor<'a> {
    tvi: &'a TypedVarInfo,
    theta: &'a [f64],
    cursor: usize,
    core: FusedCore,
    /// Per-slot site mask (Gibbs conditional path): `false` slots are
    /// scored exactly but held constant on the tape. `None` = all tracked.
    mask: Option<&'a [bool]>,
}

impl<'a> TypedFusedExecutor<'a> {
    pub fn new(tvi: &'a TypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        debug_assert_eq!(theta.len(), tvi.dim());
        Self {
            tvi,
            theta,
            cursor: 0,
            core: FusedCore::new(ctx),
            mask: None,
        }
    }

    /// [`Self::new`] with a per-slot site mask — see
    /// [`crate::model::typed_grad_fused_masked_into`].
    pub fn new_masked(
        tvi: &'a TypedVarInfo,
        theta: &'a [f64],
        ctx: Context,
        mask: &'a [bool],
    ) -> Self {
        debug_assert_eq!(theta.len(), tvi.dim());
        debug_assert_eq!(mask.len(), tvi.slots().len());
        Self {
            tvi,
            theta,
            cursor: 0,
            core: FusedCore::new(ctx),
            mask: Some(mask),
        }
    }

    /// Final log-density + tilde-statement count.
    pub fn finish(self) -> (f64, usize) {
        self.core.finish()
    }

    #[inline]
    fn next_slot(&mut self, vn: &VarName) -> &'a crate::varinfo::Slot {
        cursor_next_slot(self.tvi, &mut self.cursor, vn)
    }
}

impl<'a> TildeApi<AVar> for TypedFusedExecutor<'a> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<AVar>) -> AVar {
        let si = self.cursor;
        let slot = self.next_slot(&vn);
        if self.mask.is_some_and(|m| !m[si]) {
            self.core
                .assume_scalar_masked(self.theta, slot.unc_offset, &slot.domain, dist, &vn)
        } else {
            self.core
                .assume_scalar(self.theta, slot.unc_offset, &slot.domain, dist, &vn)
        }
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<AVar>) -> Vec<AVar> {
        let si = self.cursor;
        let slot = self.next_slot(&vn);
        if self.mask.is_some_and(|m| !m[si]) {
            self.core
                .assume_vec_masked(self.theta, slot.unc_offset, &slot.domain, dist, &vn)
        } else {
            self.core
                .assume_vec(self.theta, slot.unc_offset, &slot.domain, dist, &vn)
        }
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<AVar>) -> i64 {
        let slot = self.next_slot(&vn);
        let k = self.tvi.discrete[slot.disc_offset];
        self.core.assume_int(k, dist, &vn)
    }

    fn observe(&mut self, dist: &ScalarDist<AVar>, obs: f64) {
        self.core.observe(dist, obs);
    }

    fn observe_int(&mut self, dist: &DiscreteDist<AVar>, obs: i64) {
        self.core.observe_int(dist, obs);
    }

    fn observe_vec(&mut self, dist: &VecDist<AVar>, obs: &[f64]) {
        self.core.observe_vec(dist, obs);
    }

    fn add_obs_logp(&mut self, lp: AVar) {
        self.core.add_obs_logp(lp);
    }

    fn add_prior_logp(&mut self, lp: AVar) {
        self.core.add_prior_logp(lp);
    }

    fn reject(&mut self) {
        self.core.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.core.acc.rejected()
    }

    fn context(&self) -> Context {
        self.core.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        self.core.acc.skip_obs(n);
    }
}

/// The fused engine **through the boxed trace**: hash-addressed offsets
/// and boxed domain metadata like [`UntypedFlatExecutor`] (the dynamic
/// costs stay, deliberately), but density statements go through the same
/// [`FusedCore`] kernels and arena seeds as [`TypedFusedExecutor`] —
/// isolating trace overhead from AD overhead in the benchmarks.
pub struct UntypedFusedExecutor<'a> {
    vi: &'a UntypedVarInfo,
    offsets: crate::util::hash::FnvHashMap<VarName, usize>,
    theta: &'a [f64],
    core: FusedCore,
}

impl<'a> UntypedFusedExecutor<'a> {
    pub fn new(vi: &'a UntypedVarInfo, theta: &'a [f64], ctx: Context) -> Self {
        debug_assert_eq!(vi.num_unconstrained(), theta.len());
        Self {
            vi,
            offsets: untyped_offset_map(vi),
            theta,
            core: FusedCore::new(ctx),
        }
    }

    /// Final log-density + tilde-statement count.
    pub fn finish(self) -> (f64, usize) {
        self.core.finish()
    }

    fn lookup(&self, vn: &VarName) -> (usize, Domain) {
        let off = *self
            .offsets
            .get(vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace — dynamic structure change"));
        let rec = self.vi.get(vn).unwrap();
        (off, rec.domain.clone())
    }
}

impl<'a> TildeApi<AVar> for UntypedFusedExecutor<'a> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<AVar>) -> AVar {
        let (off, domain) = self.lookup(&vn);
        self.core.assume_scalar(self.theta, off, &domain, dist, &vn)
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<AVar>) -> Vec<AVar> {
        let (off, domain) = self.lookup(&vn);
        self.core.assume_vec(self.theta, off, &domain, dist, &vn)
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<AVar>) -> i64 {
        let rec = self
            .vi
            .get(&vn)
            .unwrap_or_else(|| panic!("variable {vn} not in trace"));
        let k = rec.value.as_int().expect("discrete assume of non-integer");
        self.core.assume_int(k, dist, &vn)
    }

    fn observe(&mut self, dist: &ScalarDist<AVar>, obs: f64) {
        self.core.observe(dist, obs);
    }

    fn observe_int(&mut self, dist: &DiscreteDist<AVar>, obs: i64) {
        self.core.observe_int(dist, obs);
    }

    fn observe_vec(&mut self, dist: &VecDist<AVar>, obs: &[f64]) {
        self.core.observe_vec(dist, obs);
    }

    fn add_obs_logp(&mut self, lp: AVar) {
        self.core.add_obs_logp(lp);
    }

    fn add_prior_logp(&mut self, lp: AVar) {
        self.core.add_prior_logp(lp);
    }

    fn reject(&mut self) {
        self.core.acc.reject();
    }

    fn rejected(&self) -> bool {
        self.core.acc.rejected()
    }

    fn context(&self) -> Context {
        self.core.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        self.core.acc.skip_obs(n);
    }
}
