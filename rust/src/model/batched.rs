//! Lane-batched executors: one tilde walk, K evaluation lanes.
//!
//! The per-statement bookkeeping of the fused path — cursor stepping,
//! dispatch into the distribution enum, node/seed pushes — is identical
//! for every chain, particle or ELBO draw evaluated at the same typed
//! layout. These executors pay it **once** and run each statement's
//! arithmetic across all K lanes in contiguous inner loops over
//! coordinate-major buffers (`theta_t[coord * K + lane]`):
//!
//! - [`BatchedFusedExecutor`] — the gradient path. Walks the tilde program
//!   exactly like [`super::executors::TypedFusedExecutor`], but evaluates
//!   each distribution's fused `logpdf_adj` kernel per lane (parameters
//!   rebuilt from the lane's values via `with_f64_params` — the same
//!   closed-form f64 arithmetic the sequential kernel runs) and records
//!   lane-strided seeds on the [`crate::ad::batch::BatchTape`]. Per-lane
//!   accumulators keep rejection independent: a lane that hits −∞ stops
//!   accumulating and its seed weights drop to zero, while the other lanes
//!   proceed untouched; [`typed_grad_batch_into`] masks the rejected
//!   lane's gradient at the output exactly as the sequential path does.
//! - [`BatchedReplayExecutor`] — the SMC path. Replays/regenerates a whole
//!   particle cloud over a [`BatchVarInfo`] in one walk, one RNG per lane
//!   (so each lane consumes exactly the draw stream its sequential replay
//!   would). Anything the one-walk-many-lanes shape cannot express
//!   bit-identically — a layout mismatch, a discrete assume (one `i64`
//!   return can't carry K diverging values), or any lane rejecting
//!   mid-walk (the sequential body early-returns, leaving later slots
//!   undrawn) — demotes: the run reports `None`, the gathered buffers are
//!   discarded, and the caller redoes the step on the per-particle path.
//!
//! Branches in model glue code resolve against lane 0's primal (the
//! [`BVar`] caveat); the tilde statements themselves never branch on lane
//! values, so per-lane results stay bit-identical to sequential runs.
//! Per-site `obs::profile` rows remain a sequential-path feature — the
//! batched executors skip profiling hooks rather than attribute one row to
//! K lanes.

use rand_core::RngCore;

use crate::ad::batch::{self, BVar};
use crate::ad::Scalar;
use crate::context::{Accumulator, Context};
use crate::dist::{bijector, DiscreteDist, Domain, ScalarDist, VecDist};
use crate::obs::metrics::{self, Counter};
use crate::varinfo::{flags, BatchVarInfo, TypedVarInfo};
use crate::varname::VarName;

use super::executors::{cursor_next_slot, ReplayScope};
use super::{Model, TildeApi};

/// Accumulate a prior-side term on one lane; returns the weight the lane's
/// seeds carry (0.0 when the term is dropped — context weight zero, or the
/// lane was already/just rejected). Mirrors `FusedCore::prior_seed_weight`.
#[inline]
fn prior_seed_weight(acc: &mut Accumulator<f64>, lp: f64, prior_w: f64) -> f64 {
    let pre = acc.rejected();
    acc.add_prior(lp);
    if !pre && !acc.rejected() {
        prior_w
    } else {
        0.0
    }
}

/// Accumulate a likelihood-side term on one lane at the window-resolved
/// weight `w`; returns the weight the lane's seeds carry. Mirrors
/// `FusedCore::lik_seed_weight`.
#[inline]
fn lik_seed_weight(acc: &mut Accumulator<f64>, lp: f64, w: f64) -> f64 {
    let pre = acc.rejected();
    acc.add_lik_weighted(lp, w);
    if !pre && !acc.rejected() {
        w
    } else {
        0.0
    }
}

/// `ws[l] = d[l] * w[l]`, with `w == 0` forced to an exact 0.0: a lane
/// whose statement weight dropped to zero must contribute *no* seed —
/// exactly as the sequential path, which never pushes the seed — even when
/// its (unused) partial is NaN/∞ and the product would not be 0.
#[inline]
fn weighted_into(ws: &mut [f64], ds: &[f64], w: &[f64]) {
    for l in 0..ws.len() {
        ws[l] = if w[l] == 0.0 { 0.0 } else { ds[l] * w[l] };
    }
}

/// Reused lane-strided buffers for the batched fused core, parked in a
/// thread-local between evaluations so the steady-state gradient path
/// allocates nothing.
#[derive(Default)]
struct BatchScratch {
    /// Per-lane values of the statement's distribution parameters.
    p0: Vec<f64>,
    p1: Vec<f64>,
    /// Per-lane constrained value / dx_dy of a scalar assume.
    xv: Vec<f64>,
    dv: Vec<f64>,
    /// Per-lane kernel outputs (SoA mirror of `ScalarAdj`/`ScalarLink`).
    lp: Vec<f64>,
    d_x: Vec<f64>,
    dp0: Vec<f64>,
    dp1: Vec<f64>,
    ladj: Vec<f64>,
    dladj: Vec<f64>,
    /// Per-lane statement seed weights and a weight-product buffer.
    w: Vec<f64>,
    ws: Vec<f64>,
    /// One lane's constrained vector / per-component density partials.
    xl: Vec<f64>,
    dxl: Vec<f64>,
    /// Component-major lane matrices for vector statements
    /// (`xm[comp * K + lane]`).
    xm: Vec<f64>,
    dxm: Vec<f64>,
    /// Simplex invlink leaves.
    yv: Vec<BVar>,
}

thread_local! {
    static BATCH_SCRATCH: std::cell::RefCell<BatchScratch> =
        std::cell::RefCell::new(BatchScratch::default());
}

fn take_batch_scratch() -> BatchScratch {
    BATCH_SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut()))
}

fn park_batch_scratch(scratch: BatchScratch) {
    BATCH_SCRATCH.with(|s| *s.borrow_mut() = scratch);
}

/// The K-lane mirror of `FusedCore`: one accumulator per lane, statement
/// kernels evaluated lane-by-lane over rebuilt f64 distributions, seeds
/// recorded lane-strided in the sequential path's seed order.
struct BatchedCore {
    accs: Vec<Accumulator<f64>>,
    ctx: Context,
    prior_w: f64,
    lanes: usize,
    s: BatchScratch,
}

impl BatchedCore {
    fn new(ctx: Context, lanes: usize) -> Self {
        Self {
            accs: (0..lanes).map(|_| Accumulator::new(ctx)).collect(),
            ctx,
            prior_w: ctx.prior_weight(),
            lanes,
            s: take_batch_scratch(),
        }
    }

    /// Per-lane final log-densities; parks the scratch for the next run.
    fn finish_into(self, lps: &mut [f64]) {
        debug_assert_eq!(lps.len(), self.lanes);
        for (lp, acc) in lps.iter_mut().zip(&self.accs) {
            *lp = acc.total();
        }
        park_batch_scratch(self.s);
    }

    #[inline]
    fn all_rejected(&self) -> bool {
        self.accs.iter().all(|a| a.rejected())
    }

    #[inline]
    fn reject_all(&mut self) {
        for a in &mut self.accs {
            a.reject();
        }
    }

    /// Advance every lane's observation counter; the window weight is
    /// lane-independent (same context), so return the shared value.
    #[inline]
    fn note_obs_all(&mut self) -> f64 {
        let mut cw = 0.0;
        for a in &mut self.accs {
            cw = a.note_obs();
        }
        cw
    }

    /// Read the K lane values of both parameter slots of a statement.
    fn read_params(s: &mut BatchScratch, ps: &[BVar], lanes: usize) {
        s.p0.resize(lanes, 0.0);
        s.p1.resize(lanes, 0.0);
        batch::with_tape(|t| {
            t.read_lanes(ps[0], &mut s.p0);
            t.read_lanes(ps[1], &mut s.p1);
        });
    }

    fn assume_scalar(
        &mut self,
        theta_t: &[f64],
        off: usize,
        domain: &Domain,
        dist: &ScalarDist<BVar>,
    ) -> BVar {
        let BatchedCore {
            ref mut accs,
            ref mut s,
            prior_w,
            lanes: k,
            ..
        } = *self;
        let (ps, np) = dist.param_vars();
        Self::read_params(s, &ps, k);
        s.xv.resize(k, 0.0);
        s.dv.resize(k, 0.0);
        s.lp.resize(k, 0.0);
        s.d_x.resize(k, 0.0);
        s.dp0.resize(k, 0.0);
        s.dp1.resize(k, 0.0);
        s.dladj.resize(k, 0.0);
        // per-lane invlink + kernel: the same closed-form f64 arithmetic
        // the sequential fused path runs, lane by lane
        for l in 0..k {
            let link = bijector::invlink_scalar_adj(domain, theta_t[off * k + l]);
            let dl = dist.with_f64_params(&[s.p0[l], s.p1[l]]);
            let adj = dl.logpdf_adj(link.x);
            s.xv[l] = link.x;
            s.dv[l] = link.dx_dy;
            s.lp[l] = adj.lp + link.ladj;
            s.d_x[l] = adj.d_x;
            s.dp0[l] = adj.d_p[0];
            s.dp1[l] = adj.d_p[1];
            s.dladj[l] = link.dladj_dy;
        }
        let x = if matches!(domain, Domain::Real) {
            BVar::leaf(off as u32, s.xv[0])
        } else {
            let idx = batch::with_tape(|t| t.push1_lanes(off as u32, &s.xv, &s.dv));
            BVar::from_node(idx, s.xv[0])
        };
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = prior_seed_weight(&mut accs[l], s.lp[l], prior_w);
        }
        // seed groups in the sequential path's order: d_x, dladj, params
        s.ws.resize(k, 0.0);
        batch::with_tape(|t| {
            weighted_into(&mut s.ws, &s.d_x, &s.w);
            t.seed_lanes(x.idx(), &s.ws);
            weighted_into(&mut s.ws, &s.dladj, &s.w);
            t.seed_lanes(off as u32, &s.ws);
            if np >= 1 {
                weighted_into(&mut s.ws, &s.dp0, &s.w);
                t.seed_lanes(ps[0].idx(), &s.ws);
            }
            if np >= 2 {
                weighted_into(&mut s.ws, &s.dp1, &s.w);
                t.seed_lanes(ps[1].idx(), &s.ws);
            }
        });
        x
    }

    fn assume_vec(
        &mut self,
        theta_t: &[f64],
        off: usize,
        domain: &Domain,
        dist: &VecDist<BVar>,
    ) -> Vec<BVar> {
        let BatchedCore {
            ref mut accs,
            ref mut s,
            prior_w,
            lanes: k,
            ..
        } = *self;
        let n = domain.constrained_dim();
        let (ps, np) = dist.param_vars();
        Self::read_params(s, &ps, k);
        s.xm.resize(n * k, 0.0);
        s.ladj.clear();
        s.ladj.resize(k, 0.0);
        // value nodes + per-lane ladj, mirroring `fused_assume_vec`
        let (out, ladj_node) = match domain {
            Domain::RealVec(_) => {
                for i in 0..n {
                    for l in 0..k {
                        s.xm[i * k + l] = theta_t[(off + i) * k + l];
                    }
                }
                let out: Vec<BVar> = (0..n)
                    .map(|i| BVar::leaf((off + i) as u32, s.xm[i * k]))
                    .collect();
                (out, BVar::constant(0.0))
            }
            Domain::PositiveVec(_) => {
                let mut out = Vec::with_capacity(n);
                s.xv.resize(k, 0.0);
                for i in 0..n {
                    for l in 0..k {
                        let y = theta_t[(off + i) * k + l];
                        let x = y.exp();
                        s.ladj[l] += y;
                        s.xv[l] = x;
                        s.xm[i * k + l] = x;
                    }
                    // value = dx/dy = exp(y), as in the sequential push
                    let idx = batch::with_tape(|t| t.push1_lanes((off + i) as u32, &s.xv, &s.xv));
                    out.push(BVar::from_node(idx, s.xv[0]));
                }
                (out, BVar::constant(0.0))
            }
            Domain::Simplex(_) => {
                let m = domain.unconstrained_dim();
                s.yv.clear();
                s.yv.extend(
                    (0..m).map(|i| BVar::leaf((off + i) as u32, theta_t[(off + i) * k])),
                );
                let mut out = vec![BVar::constant(0.0); n];
                // generic stick-breaking over BVar: node-for-node the
                // sequential AVar structure, per-lane identical arithmetic
                let ladj = bijector::invlink_slice(domain, &s.yv, &mut out);
                s.xv.resize(k, 0.0);
                batch::with_tape(|t| {
                    for (i, x) in out.iter().enumerate() {
                        t.read_lanes(*x, &mut s.xv);
                        s.xm[i * k..i * k + k].copy_from_slice(&s.xv);
                    }
                    t.read_lanes(ladj, &mut s.ladj);
                });
                (out, ladj)
            }
            other => panic!("vector assume over scalar/discrete domain {other:?}"),
        };
        // per-lane density kernel
        s.dxm.resize(n * k, 0.0);
        s.lp.resize(k, 0.0);
        s.dp0.resize(k, 0.0);
        s.dp1.resize(k, 0.0);
        for l in 0..k {
            s.xl.clear();
            s.xl.extend((0..n).map(|i| s.xm[i * k + l]));
            s.dxl.clear();
            s.dxl.resize(n, 0.0);
            let dl = dist.with_f64_params(&[s.p0[l], s.p1[l]]);
            let adj = dl.logpdf_adj(&s.xl, &mut s.dxl);
            for i in 0..n {
                s.dxm[i * k + l] = s.dxl[i];
            }
            s.lp[l] = adj.lp + s.ladj[l];
            s.dp0[l] = adj.d_p[0];
            s.dp1[l] = adj.d_p[1];
        }
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = prior_seed_weight(&mut accs[l], s.lp[l], prior_w);
        }
        // seeds in the sequential `seed_assume_vec` order:
        // components, ladj (domain-dependent), params
        s.ws.resize(k, 0.0);
        batch::with_tape(|t| {
            for (i, x) in out.iter().enumerate() {
                weighted_into(&mut s.ws, &s.dxm[i * k..i * k + k], &s.w);
                t.seed_lanes(x.idx(), &s.ws);
            }
            match domain {
                Domain::PositiveVec(nn) => {
                    for i in 0..*nn {
                        t.seed_lanes((off + i) as u32, &s.w);
                    }
                }
                Domain::Simplex(_) => t.seed_lanes(ladj_node.idx(), &s.w),
                _ => {}
            }
            if np >= 1 {
                weighted_into(&mut s.ws, &s.dp0, &s.w);
                t.seed_lanes(ps[0].idx(), &s.ws);
            }
            if np >= 2 {
                weighted_into(&mut s.ws, &s.dp1, &s.w);
                t.seed_lanes(ps[1].idx(), &s.ws);
            }
        });
        out
    }

    /// Score a discrete assume whose (lane-uniform) value `kval` the
    /// caller fetched from the shared typed trace.
    fn assume_int(&mut self, kval: i64, dist: &DiscreteDist<BVar>) -> i64 {
        let BatchedCore {
            ref mut accs,
            ref mut s,
            prior_w,
            lanes: k,
            ..
        } = *self;
        let pv = dist.param_var();
        s.p0.resize(k, 0.0);
        batch::with_tape(|t| t.read_lanes(pv.unwrap_or_else(|| BVar::constant(0.0)), &mut s.p0));
        s.lp.resize(k, 0.0);
        s.dp0.resize(k, 0.0);
        for l in 0..k {
            let (lp, dp) = dist.with_f64_param(s.p0[l]).logpmf_adj(kval);
            s.lp[l] = lp;
            s.dp0[l] = dp;
        }
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = prior_seed_weight(&mut accs[l], s.lp[l], prior_w);
        }
        if let Some(p) = pv {
            s.ws.resize(k, 0.0);
            weighted_into(&mut s.ws, &s.dp0, &s.w);
            batch::with_tape(|t| t.seed_lanes(p.idx(), &s.ws));
        }
        kval
    }

    fn observe(&mut self, dist: &ScalarDist<BVar>, obs: f64) {
        let cw = self.note_obs_all();
        if cw == 0.0 {
            return; // out-of-window / zero-weight: no kernel, no seeds
        }
        let BatchedCore {
            ref mut accs,
            ref mut s,
            lanes: k,
            ..
        } = *self;
        let (ps, np) = dist.param_vars();
        Self::read_params(s, &ps, k);
        s.lp.resize(k, 0.0);
        s.dp0.resize(k, 0.0);
        s.dp1.resize(k, 0.0);
        for l in 0..k {
            let adj = dist.with_f64_params(&[s.p0[l], s.p1[l]]).logpdf_adj(obs);
            s.lp[l] = adj.lp;
            s.dp0[l] = adj.d_p[0];
            s.dp1[l] = adj.d_p[1];
        }
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = lik_seed_weight(&mut accs[l], s.lp[l], cw);
        }
        s.ws.resize(k, 0.0);
        batch::with_tape(|t| {
            if np >= 1 {
                weighted_into(&mut s.ws, &s.dp0, &s.w);
                t.seed_lanes(ps[0].idx(), &s.ws);
            }
            if np >= 2 {
                weighted_into(&mut s.ws, &s.dp1, &s.w);
                t.seed_lanes(ps[1].idx(), &s.ws);
            }
        });
    }

    fn observe_int(&mut self, dist: &DiscreteDist<BVar>, obs: i64) {
        let cw = self.note_obs_all();
        if cw == 0.0 {
            return;
        }
        let BatchedCore {
            ref mut accs,
            ref mut s,
            lanes: k,
            ..
        } = *self;
        let pv = dist.param_var();
        s.p0.resize(k, 0.0);
        batch::with_tape(|t| t.read_lanes(pv.unwrap_or_else(|| BVar::constant(0.0)), &mut s.p0));
        s.lp.resize(k, 0.0);
        s.dp0.resize(k, 0.0);
        for l in 0..k {
            let (lp, dp) = dist.with_f64_param(s.p0[l]).logpmf_adj(obs);
            s.lp[l] = lp;
            s.dp0[l] = dp;
        }
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = lik_seed_weight(&mut accs[l], s.lp[l], cw);
        }
        if let Some(p) = pv {
            s.ws.resize(k, 0.0);
            weighted_into(&mut s.ws, &s.dp0, &s.w);
            batch::with_tape(|t| t.seed_lanes(p.idx(), &s.ws));
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<BVar>, obs: &[f64]) {
        let cw = self.note_obs_all();
        if cw == 0.0 {
            return;
        }
        let BatchedCore {
            ref mut accs,
            ref mut s,
            lanes: k,
            ..
        } = *self;
        let (ps, np) = dist.param_vars();
        Self::read_params(s, &ps, k);
        s.lp.resize(k, 0.0);
        s.dp0.resize(k, 0.0);
        s.dp1.resize(k, 0.0);
        for l in 0..k {
            s.dxl.clear();
            s.dxl.resize(obs.len(), 0.0);
            let adj = dist
                .with_f64_params(&[s.p0[l], s.p1[l]])
                .logpdf_adj(obs, &mut s.dxl);
            s.lp[l] = adj.lp;
            s.dp0[l] = adj.d_p[0];
            s.dp1[l] = adj.d_p[1];
        }
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = lik_seed_weight(&mut accs[l], s.lp[l], cw);
        }
        s.ws.resize(k, 0.0);
        batch::with_tape(|t| {
            if np >= 1 {
                weighted_into(&mut s.ws, &s.dp0, &s.w);
                t.seed_lanes(ps[0].idx(), &s.ws);
            }
            if np >= 2 {
                weighted_into(&mut s.ws, &s.dp1, &s.w);
                t.seed_lanes(ps[1].idx(), &s.ws);
            }
        });
    }

    fn add_obs_logp(&mut self, lp: BVar) {
        let cw = self.note_obs_all();
        if cw == 0.0 {
            return;
        }
        let BatchedCore {
            ref mut accs,
            ref mut s,
            lanes: k,
            ..
        } = *self;
        s.lp.resize(k, 0.0);
        batch::with_tape(|t| t.read_lanes(lp, &mut s.lp));
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = lik_seed_weight(&mut accs[l], s.lp[l], cw);
        }
        batch::with_tape(|t| t.seed_lanes(lp.idx(), &s.w));
    }

    fn add_prior_logp(&mut self, lp: BVar) {
        let BatchedCore {
            ref mut accs,
            ref mut s,
            prior_w,
            lanes: k,
            ..
        } = *self;
        s.lp.resize(k, 0.0);
        batch::with_tape(|t| t.read_lanes(lp, &mut s.lp));
        s.w.resize(k, 0.0);
        for l in 0..k {
            s.w[l] = prior_seed_weight(&mut accs[l], s.lp[l], prior_w);
        }
        batch::with_tape(|t| t.seed_lanes(lp.idx(), &s.w));
    }
}

/// Evaluates per-lane log-densities and lane-strided gradient seeds from a
/// coordinate-major unconstrained buffer over one frozen [`TypedVarInfo`]
/// layout — the K-lane form of
/// [`super::executors::TypedFusedExecutor`]. Cursor semantics are
/// identical (a dynamic structure change panics the same way); discrete
/// sites read the shared trace's lane-uniform conditioned value.
pub struct BatchedFusedExecutor<'a> {
    tvi: &'a TypedVarInfo,
    theta_t: &'a [f64],
    cursor: usize,
    core: BatchedCore,
}

impl<'a> BatchedFusedExecutor<'a> {
    /// `theta_t` is coordinate-major: `theta_t[coord * lanes + lane]`.
    pub fn new(tvi: &'a TypedVarInfo, theta_t: &'a [f64], lanes: usize, ctx: Context) -> Self {
        debug_assert_eq!(theta_t.len(), tvi.dim() * lanes);
        Self {
            tvi,
            theta_t,
            cursor: 0,
            core: BatchedCore::new(ctx, lanes),
        }
    }

    /// Per-lane final log-densities.
    pub fn finish_into(self, lps: &mut [f64]) {
        self.core.finish_into(lps);
    }

    #[inline]
    fn next_slot(&mut self, vn: &VarName) -> &'a crate::varinfo::Slot {
        cursor_next_slot(self.tvi, &mut self.cursor, vn)
    }
}

impl<'a> TildeApi<BVar> for BatchedFusedExecutor<'a> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<BVar>) -> BVar {
        let slot = self.next_slot(&vn);
        self.core
            .assume_scalar(self.theta_t, slot.unc_offset, &slot.domain, dist)
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<BVar>) -> Vec<BVar> {
        let slot = self.next_slot(&vn);
        self.core
            .assume_vec(self.theta_t, slot.unc_offset, &slot.domain, dist)
    }

    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<BVar>) -> i64 {
        let slot = self.next_slot(&vn);
        let k = self.tvi.discrete[slot.disc_offset];
        self.core.assume_int(k, dist)
    }

    fn observe(&mut self, dist: &ScalarDist<BVar>, obs: f64) {
        self.core.observe(dist, obs);
    }

    fn observe_int(&mut self, dist: &DiscreteDist<BVar>, obs: i64) {
        self.core.observe_int(dist, obs);
    }

    fn observe_vec(&mut self, dist: &VecDist<BVar>, obs: &[f64]) {
        self.core.observe_vec(dist, obs);
    }

    fn add_obs_logp(&mut self, lp: BVar) {
        self.core.add_obs_logp(lp);
    }

    fn add_prior_logp(&mut self, lp: BVar) {
        self.core.add_prior_logp(lp);
    }

    fn reject(&mut self) {
        // a model-level reject applies to the program, hence to all lanes
        self.core.reject_all();
    }

    fn rejected(&self) -> bool {
        // the body may only early-return once *every* lane is done
        self.core.all_rejected()
    }

    fn context(&self) -> Context {
        self.core.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        for a in &mut self.core.accs {
            a.skip_obs(n);
        }
    }
}

thread_local! {
    /// Transpose scratch for [`typed_grad_batch_into`] (lane-major ↔
    /// coordinate-major).
    static XPOSE: std::cell::RefCell<(Vec<f64>, Vec<f64>)> =
        std::cell::RefCell::new((Vec::new(), Vec::new()));
}

/// K-lane arena-fused gradient through the typed layout, written into
/// caller-owned buffers — the lane-batched `logp_grad_into`.
///
/// `thetas` and `grads` are **lane-major** (`[l * dim .. (l+1) * dim]` is
/// lane `l`), matching how samplers hold per-chain/per-draw states; the
/// transpose to the tape's coordinate-major layout happens here, into
/// retained thread-local scratch. Each lane's value and gradient are
/// bit-identical to a sequential [`super::typed_grad_fused_into`] call at
/// that lane's θ; a lane whose evaluation rejects (or goes non-finite)
/// gets its gradient zeroed without disturbing the other lanes.
pub fn typed_grad_batch_into(
    model: &dyn Model,
    tvi: &TypedVarInfo,
    thetas: &[f64],
    lanes: usize,
    ctx: Context,
    lps: &mut [f64],
    grads: &mut [f64],
) {
    let dim = tvi.dim();
    assert!(lanes > 0);
    assert_eq!(thetas.len(), dim * lanes);
    assert_eq!(lps.len(), lanes);
    assert_eq!(grads.len(), dim * lanes);
    metrics::add(Counter::GradEvals, lanes as u64);
    metrics::inc(Counter::BatchedEvals);
    metrics::add(Counter::BatchedLanes, lanes as u64);
    XPOSE.with(|x| {
        let (theta_t, grad_t) = &mut *x.borrow_mut();
        theta_t.resize(dim * lanes, 0.0);
        for l in 0..lanes {
            for i in 0..dim {
                theta_t[i * lanes + l] = thetas[l * dim + i];
            }
        }
        batch::begin(theta_t, dim, lanes);
        let mut exec = BatchedFusedExecutor::new(tvi, theta_t, lanes, ctx);
        model.eval_batch(&mut exec);
        exec.finish_into(lps);
        if lps.iter().all(|lp| !lp.is_finite()) {
            // every lane rejected: mirror the sequential early-out
            metrics::add(Counter::RejectedEvals, lanes as u64);
            grads.fill(0.0);
            return;
        }
        grad_t.resize(dim * lanes, 0.0);
        batch::backward_into(grad_t);
        for l in 0..lanes {
            let g = &mut grads[l * dim..(l + 1) * dim];
            if !lps[l].is_finite() {
                metrics::inc(Counter::RejectedEvals);
                g.fill(0.0);
            } else {
                for i in 0..dim {
                    g[i] = grad_t[i * lanes + l];
                }
            }
        }
    });
}

/// Outcome of one batched replay: per-lane incremental log-weights plus
/// the shared observation count (lanes walk the same tilde program, so the
/// visit count cannot differ across lanes).
#[derive(Clone, Debug)]
pub struct BatchedReplayReport {
    pub deltas: Vec<f64>,
    pub obs_total: usize,
}

/// Replay-with-regenerate for a whole particle cloud in one walk over a
/// [`BatchVarInfo`] — the K-lane mirror of
/// [`super::executors::TypedReplayExecutor`]. Each lane has its own RNG
/// (freshly seeded per step by the cloud, so a demoted step replays
/// identically on the sequential path), its own accumulator, and its own
/// RESAMPLE/LOCKED flags; the cursor, the observation counter and the
/// layout check are shared.
///
/// Returns `None` (demote) from [`BatchedReplayExecutor::run`] when the
/// walk cannot be bit-identical to K sequential replays: layout mismatch,
/// a discrete assume, or any lane rejecting mid-walk. The caller discards
/// the gathered buffers and redoes the step per particle.
pub struct BatchedReplayExecutor<'a, R: RngCore> {
    rngs: &'a mut [R],
    bvi: &'a mut BatchVarInfo,
    accs: Vec<Accumulator<f64>>,
    ctx: Context,
    scope: ReplayScope<'a>,
    lo: usize,
    hi: usize,
    cursor: usize,
    obs_seen: usize,
    ok: bool,
    locking_done: bool,
    // lane scratch
    p0: Vec<f64>,
    p1: Vec<f64>,
    vbuf: Vec<f64>,
    xlb: Vec<f64>,
    xmb: Vec<f64>,
}

impl<'a, R: RngCore> BatchedReplayExecutor<'a, R> {
    pub fn new(
        rngs: &'a mut [R],
        bvi: &'a mut BatchVarInfo,
        ctx: Context,
        scope: ReplayScope<'a>,
    ) -> Self {
        let (lo, hi) = ctx.obs_window();
        let k = bvi.lanes();
        debug_assert_eq!(rngs.len(), k);
        Self {
            rngs,
            bvi,
            accs: (0..k).map(|_| Accumulator::new(ctx)).collect(),
            ctx,
            scope,
            lo,
            hi,
            cursor: 0,
            obs_seen: 0,
            ok: true,
            locking_done: hi == 0 || hi == usize::MAX,
        }
    }

    /// Run `model` once across all lanes; `None` demotes the step to the
    /// per-particle path (the batch buffers are then discarded unused).
    pub fn run(
        model: &dyn Model,
        rngs: &'a mut [R],
        bvi: &'a mut BatchVarInfo,
        ctx: Context,
        scope: ReplayScope<'a>,
    ) -> Option<BatchedReplayReport> {
        batch::begin(&[], 0, bvi.lanes());
        let mut exec = BatchedReplayExecutor::new(rngs, bvi, ctx, scope);
        model.eval_batch(&mut exec);
        exec.finalize()
    }

    fn finalize(mut self) -> Option<BatchedReplayReport> {
        // rejected lanes already demoted, so unvisited slots here always
        // mean a structure change
        if !self.ok || self.cursor != self.bvi.slots().len() {
            return None;
        }
        if !self.locking_done {
            for i in 0..self.cursor {
                for l in 0..self.bvi.lanes() {
                    self.bvi.flag_slot(i, l, flags::LOCKED);
                }
            }
        }
        Some(BatchedReplayReport {
            deltas: self.accs.iter().map(|a| a.total()).collect(),
            obs_total: self.obs_seen,
        })
    }

    #[inline]
    fn next_slot(&mut self, vn: &VarName, domain: &Domain) -> Option<usize> {
        if !self.ok {
            return None;
        }
        let i = self.cursor;
        let ok = match self.bvi.slots().get(i) {
            Some(s) => s.vn == *vn && s.domain.compatible(domain),
            None => false,
        };
        if ok {
            self.cursor += 1;
            Some(i)
        } else {
            self.ok = false;
            None
        }
    }

    #[inline]
    fn note_obs(&mut self) -> bool {
        let i = self.obs_seen;
        self.obs_seen += 1;
        if self.obs_seen == self.hi && !self.locking_done {
            for s in 0..self.cursor {
                for l in 0..self.bvi.lanes() {
                    self.bvi.flag_slot(s, l, flags::LOCKED);
                }
            }
            self.locking_done = true;
        }
        i >= self.lo && i < self.hi
    }

    #[inline]
    fn score_assume(&mut self, si: usize, l: usize, lp: f64) {
        let in_window = self.obs_seen >= self.lo && self.obs_seen < self.hi;
        let proposed = match self.scope {
            ReplayScope::Unscoped => true,
            ReplayScope::Mask(m) => m[si],
            ReplayScope::Eval => false,
        };
        if in_window && !proposed {
            self.accs[l].add_lik(lp);
        } else {
            self.accs[l].add_prior(lp);
        }
    }

    /// A sequential replay's body early-returns on rejection, leaving
    /// later RESAMPLE slots undrawn — a shape one shared walk cannot
    /// reproduce per lane. Any lane rejecting therefore demotes the step.
    #[inline]
    fn demote_if_rejected(&mut self) {
        if self.accs.iter().any(|a| a.rejected()) {
            self.ok = false;
        }
    }

    fn read_params(&mut self, ps: &[BVar]) {
        let k = self.bvi.lanes();
        self.p0.resize(k, 0.0);
        self.p1.resize(k, 0.0);
        batch::with_tape(|t| {
            t.read_lanes(ps[0], &mut self.p0);
            t.read_lanes(ps[1], &mut self.p1);
        });
    }
}

impl<'a, R: RngCore> TildeApi<BVar> for BatchedReplayExecutor<'a, R> {
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<BVar>) -> BVar {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            None => return BVar::constant(0.0),
        };
        let (ps, _np) = dist.param_vars();
        self.read_params(&ps);
        let k = self.bvi.lanes();
        let co = self.bvi.slots()[si].cons_offset;
        self.vbuf.resize(k, 0.0);
        for l in 0..k {
            let dl = dist.with_f64_params(&[self.p0[l], self.p1[l]]);
            let x = if self.bvi.is_slot_flagged(si, l, flags::RESAMPLE) {
                let x = dl.sample(&mut self.rngs[l]);
                // the lane's own domain: Interval bounds may be lane-varying
                self.bvi.write_slot_f64_lane(si, l, x, &dl.domain());
                self.bvi.clear_slot_flag(si, l, flags::RESAMPLE);
                x
            } else {
                self.bvi.cons(co, l)
            };
            self.vbuf[l] = x;
            let lp = dl.logpdf(x);
            self.score_assume(si, l, lp);
        }
        self.demote_if_rejected();
        let idx = batch::with_tape(|t| t.push0_lanes(&self.vbuf));
        BVar::from_node(idx, self.vbuf[0])
    }

    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<BVar>) -> Vec<BVar> {
        let domain = dist.domain();
        let si = match self.next_slot(&vn, &domain) {
            Some(i) => i,
            None => return vec![BVar::constant(0.0); domain.constrained_dim()],
        };
        let (ps, _np) = dist.param_vars();
        self.read_params(&ps);
        let k = self.bvi.lanes();
        let (co, cl) = {
            let s = &self.bvi.slots()[si];
            (s.cons_offset, s.cons_len)
        };
        self.xmb.resize(cl * k, 0.0);
        for l in 0..k {
            let dl = dist.with_f64_params(&[self.p0[l], self.p1[l]]);
            if self.bvi.is_slot_flagged(si, l, flags::RESAMPLE) {
                let xs = dl.sample(&mut self.rngs[l]);
                self.bvi.write_slot_vec_lane(si, l, &xs, &dl.domain());
                self.bvi.clear_slot_flag(si, l, flags::RESAMPLE);
                for (i, &x) in xs.iter().enumerate() {
                    self.xmb[i * k + l] = x;
                }
            } else {
                for i in 0..cl {
                    self.xmb[i * k + l] = self.bvi.cons(co + i, l);
                }
            }
            self.xlb.clear();
            self.xlb.extend((0..cl).map(|i| self.xmb[i * k + l]));
            let lp = dl.logpdf(&self.xlb);
            self.score_assume(si, l, lp);
        }
        self.demote_if_rejected();
        (0..cl)
            .map(|i| {
                let idx = batch::with_tape(|t| t.push0_lanes(&self.xmb[i * k..i * k + k]));
                BVar::from_node(idx, self.xmb[i * k])
            })
            .collect()
    }

    fn assume_int(&mut self, _vn: VarName, _dist: &DiscreteDist<BVar>) -> i64 {
        // one i64 return cannot carry K diverging lane values — demote
        self.ok = false;
        0
    }

    fn observe(&mut self, dist: &ScalarDist<BVar>, obs: f64) {
        if !self.ok {
            return;
        }
        if self.note_obs() {
            let (ps, _np) = dist.param_vars();
            self.read_params(&ps);
            for l in 0..self.bvi.lanes() {
                let lp = dist.with_f64_params(&[self.p0[l], self.p1[l]]).logpdf(obs);
                self.accs[l].add_lik(lp);
            }
            self.demote_if_rejected();
        }
    }

    fn observe_int(&mut self, dist: &DiscreteDist<BVar>, obs: i64) {
        if !self.ok {
            return;
        }
        if self.note_obs() {
            let pv = dist.param_var();
            let k = self.bvi.lanes();
            self.p0.resize(k, 0.0);
            batch::with_tape(|t| {
                t.read_lanes(pv.unwrap_or_else(|| BVar::constant(0.0)), &mut self.p0)
            });
            for l in 0..k {
                let lp = dist.with_f64_param(self.p0[l]).logpmf(obs);
                self.accs[l].add_lik(lp);
            }
            self.demote_if_rejected();
        }
    }

    fn observe_vec(&mut self, dist: &VecDist<BVar>, obs: &[f64]) {
        if !self.ok {
            return;
        }
        if self.note_obs() {
            let (ps, _np) = dist.param_vars();
            self.read_params(&ps);
            for l in 0..self.bvi.lanes() {
                let lp = dist.with_f64_params(&[self.p0[l], self.p1[l]]).logpdf(obs);
                self.accs[l].add_lik(lp);
            }
            self.demote_if_rejected();
        }
    }

    fn add_obs_logp(&mut self, lp: BVar) {
        if !self.ok {
            return;
        }
        if self.note_obs() {
            let k = self.bvi.lanes();
            self.vbuf.resize(k, 0.0);
            batch::with_tape(|t| t.read_lanes(lp, &mut self.vbuf));
            for l in 0..k {
                self.accs[l].add_lik(self.vbuf[l]);
            }
            self.demote_if_rejected();
        }
    }

    fn add_prior_logp(&mut self, lp: BVar) {
        if !self.ok {
            return;
        }
        let k = self.bvi.lanes();
        self.vbuf.resize(k, 0.0);
        batch::with_tape(|t| t.read_lanes(lp, &mut self.vbuf));
        for l in 0..k {
            self.accs[l].add_prior(self.vbuf[l]);
        }
        self.demote_if_rejected();
    }

    fn reject(&mut self) {
        for a in &mut self.accs {
            a.reject();
        }
        self.ok = false;
    }

    fn rejected(&self) -> bool {
        // demotion short-circuits the rest of the body: the run's buffers
        // are discarded either way
        !self.ok
    }

    fn context(&self) -> Context {
        self.ctx
    }

    fn skip_obs(&mut self, n: usize) {
        for _ in 0..n {
            let _ = self.note_obs();
        }
    }
}
