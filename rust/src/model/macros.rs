//! The tilde DSL (paper §2.1).
//!
//! [`model!`] defines a model type: named data fields plus a generative
//! body written once, generically over the AD scalar `T`. Inside the body,
//! the tilde macros mirror DynamicPPL's notation:
//!
//! ```text
//! DynamicPPL (Julia)                     this crate (Rust)
//! ----------------------------------     ----------------------------------
//! s ~ InverseGamma(2, 3)                 let s = tilde!(api, s ~ InverseGamma(c(2.0), c(3.0)));
//! w ~ MvNormal(D, 1.0)                   let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), c(1.0), d));
//! h[t] ~ Normal(mu, sd)                  let h_t = tilde!(api, h[t] ~ Normal(mu, sd));
//! y[i] ~ Normal(yhat, s)                 obs!(api, this.y[i] ~ Normal(yhat, s));
//! y .~ Normal.(X*w, s)                   obs_iid!(api, &self.y .~ Normal(mu, s));
//! @logpdf() = -Inf; return               api.reject(); return;
//! ```
//!
//! `c(x)` is shorthand for `T::constant(x)` (re-exported in the prelude as
//! [`crate::model::c`]).

/// Lift an `f64` literal/expression to the generic scalar type. Free
/// function form of `T::constant` that infers `T` from context.
#[inline]
pub fn c<T: crate::ad::Scalar>(x: f64) -> T {
    T::constant(x)
}

/// Define a model type: data fields + generative body.
///
/// ```ignore
/// model! {
///     /// Bayesian linear regression.
///     pub LinReg {
///         x: Vec<Vec<f64>>,
///         y: Vec<f64>,
///     }
///     fn body<T>(this, api) {
///         let s = tilde!(api, s ~ InverseGamma(c(2.0), c(3.0)));
///         let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), c(1.0), this.x[0].len()));
///         for i in 0..this.y.len() {
///             let mut mu = c::<T>(0.0);
///             for j in 0..w.len() { mu = mu + w[j] * this.x[i][j]; }
///             obs!(api, this.y[i] ~ Normal(mu, s.sqrt()));
///         }
///     }
/// }
/// ```
#[macro_export]
macro_rules! model {
    (
        $(#[$meta:meta])*
        pub $name:ident {
            $($(#[$fmeta:meta])* $field:ident : $fty:ty),* $(,)?
        }
        fn body<$T:ident>($self_:ident, $api:ident) $body:block
    ) => {
        $(#[$meta])*
        #[derive(Clone, Debug)]
        pub struct $name {
            $($(#[$fmeta])* pub $field: $fty),*
        }

        impl $name {
            /// The generative body, generic over the AD scalar type.
            pub fn eval_generic<$T: $crate::ad::Scalar>(
                &self,
                $api: &mut dyn $crate::model::TildeApi<$T>,
            ) {
                let $self_ = self;
                let _ = &$self_;
                $body
            }
        }

        impl $crate::model::Model for $name {
            fn name(&self) -> &str {
                stringify!($name)
            }
            fn eval_f64(&self, api: &mut dyn $crate::model::TildeApi<f64>) {
                self.eval_generic(api)
            }
            fn eval_dual(
                &self,
                api: &mut dyn $crate::model::TildeApi<$crate::ad::forward::Dual>,
            ) {
                self.eval_generic(api)
            }
            fn eval_tape(
                &self,
                api: &mut dyn $crate::model::TildeApi<$crate::ad::reverse::TVar>,
            ) {
                self.eval_generic(api)
            }
            fn eval_arena(
                &self,
                api: &mut dyn $crate::model::TildeApi<$crate::ad::arena::AVar>,
            ) {
                self.eval_generic(api)
            }
            fn eval_batch(
                &self,
                api: &mut dyn $crate::model::TildeApi<$crate::ad::batch::BVar>,
            ) {
                self.eval_generic(api)
            }
            fn eval_record(
                &self,
                api: &mut dyn $crate::model::TildeApi<$crate::ad::record::RVar>,
            ) {
                self.eval_generic(api)
            }
        }
    };
}

/// Scalar parameter: `tilde!(api, name ~ Dist(args…))` or
/// `tilde!(api, name[idx] ~ Dist(args…))`. Evaluates to the parameter's
/// value (type `T`).
#[macro_export]
macro_rules! tilde {
    ($api:expr, $name:ident ~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::ScalarDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.assume($crate::varname::VarName::new(stringify!($name)), &__d)
    }};
    ($api:expr, $name:ident [ $idx:expr ] ~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::ScalarDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.assume(
            $crate::varname::VarName::indexed(stringify!($name), $idx),
            &__d,
        )
    }};
}

/// Vector parameter: `tilde_vec!(api, name ~ VecDistVariant(args…))`.
/// Evaluates to `Vec<T>` in constrained space.
#[macro_export]
macro_rules! tilde_vec {
    ($api:expr, $name:ident ~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::VecDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.assume_vec($crate::varname::VarName::new(stringify!($name)), &__d)
    }};
    ($api:expr, $name:ident [ $idx:expr ] ~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::VecDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.assume_vec(
            $crate::varname::VarName::indexed(stringify!($name), $idx),
            &__d,
        )
    }};
}

/// Discrete parameter: `tilde_int!(api, name ~ DiscreteDistVariant(args…))`.
/// Evaluates to `i64`.
#[macro_export]
macro_rules! tilde_int {
    ($api:expr, $name:ident ~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::DiscreteDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.assume_int($crate::varname::VarName::new(stringify!($name)), &__d)
    }};
    ($api:expr, $name:ident [ $idx:expr ] ~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::DiscreteDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.assume_int(
            $crate::varname::VarName::indexed(stringify!($name), $idx),
            &__d,
        )
    }};
}

/// Continuous observation: `obs!(api, value ~ Dist(args…))`.
#[macro_export]
macro_rules! obs {
    ($api:expr, $val:expr => $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::ScalarDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.observe(&__d, $val)
    }};
    ($api:expr, $val:expr , ~ $dist:ident ( $($arg:expr),* $(,)? )) => {
        $crate::obs!($api, $val => $dist($($arg),*))
    };
}

/// Discrete observation: `obs_int!(api, value => Dist(args…))`.
#[macro_export]
macro_rules! obs_int {
    ($api:expr, $val:expr => $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::DiscreteDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.observe_int(&__d, $val)
    }};
}

/// Vector observation: `obs_vec!(api, slice => VecDistVariant(args…))`.
#[macro_export]
macro_rules! obs_vec {
    ($api:expr, $val:expr => $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::VecDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.observe_vec(&__d, $val)
    }};
}

/// Broadcast iid observation (the paper's `.~`):
/// `obs_iid!(api, slice .~ Dist(args…))`.
#[macro_export]
macro_rules! obs_iid {
    ($api:expr, $vals:expr , .~ $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::ScalarDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.observe_iid(&__d, $vals)
    }};
    ($api:expr, $vals:expr => $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::ScalarDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.observe_iid(&__d, $vals)
    }};
}

/// Broadcast iid discrete observation:
/// `obs_int_iid!(api, slice => Dist(args…))`.
#[macro_export]
macro_rules! obs_int_iid {
    ($api:expr, $vals:expr => $dist:ident ( $($arg:expr),* $(,)? )) => {{
        let __d = $crate::dist::DiscreteDist::$dist($crate::dist::$dist::new($($arg),*));
        $api.observe_int_iid(&__d, $vals)
    }};
}

/// Early-rejection guard: returns from the model body if rejected
/// (paper §3.3: `@logpdf() = -Inf; return`).
#[macro_export]
macro_rules! check_reject {
    ($api:expr) => {
        if $api.rejected() {
            return;
        }
    };
}
