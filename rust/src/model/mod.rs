//! Model definition and execution (the paper's §2.1 `@model` DSL).
//!
//! A model is written **once**, generically over the AD scalar type, as a
//! sequence of tilde statements against the [`TildeApi`]. The [`Model`]
//! trait exposes five monomorphized entry points (`f64`, forward dual,
//! reverse tape, arena-fused, lane-batched) so model objects stay `dyn`-safe while the
//! body compiles to specialized code per scalar type — the Rust rendering
//! of Julia's compile-on-first-call specialization.
//!
//! Executors implementing [`TildeApi`]:
//! - [`executors::SampleExecutor`] — draws missing variables from their
//!   priors into an [`UntypedVarInfo`] (first contact with a model, prior
//!   sampling, particle-style resampling).
//! - [`executors::TypedExecutor`] — evaluates the log-density from a flat
//!   unconstrained parameter slice using the fixed [`TypedVarInfo`] layout
//!   (cursor walk; no hashing). Generic over `T` → used by both plain
//!   evaluation and AD gradients.
//! - [`executors::UntypedFlatExecutor`] — same semantics but addresses
//!   parameters through the boxed trace's hash map on every tilde: the
//!   pre-specialization dynamic path the benchmarks contrast against.
//! - [`executors::TypedFusedExecutor`] / [`executors::UntypedFusedExecutor`]
//!   — the arena-fused gradient path: same cursor/hash addressing as their
//!   generic counterparts, but each tilde statement runs one analytic
//!   `logpdf_adj` kernel and records gradient *seeds* instead of taping
//!   every scalar op (`Backend::ReverseFused`, the native default).

pub mod batched;
pub mod compiled;
pub mod executors;
#[macro_use]
pub mod macros;

use crate::ad::arena::AVar;
use crate::ad::forward::Dual;
use crate::ad::reverse::TVar;
use crate::ad::Scalar;
use crate::context::Context;
use crate::dist::{DiscreteDist, ScalarDist, VecDist};
use crate::obs::metrics::{self, Counter};
use crate::varname::VarName;

/// The tilde-statement interface models are written against.
///
/// `assume*` introduce **parameters** (returning their current/drawn
/// value); `observe*` score **data**. `reject` implements the paper's
/// early-rejection idiom (§3.3) — model code should `return` after calling
/// it; the `tilde!` macros insert the check automatically.
pub trait TildeApi<T: Scalar> {
    /// `v ~ dist` for a scalar parameter.
    fn assume(&mut self, vn: VarName, dist: &ScalarDist<T>) -> T;
    /// `v ~ dist` for a vector parameter.
    fn assume_vec(&mut self, vn: VarName, dist: &VecDist<T>) -> Vec<T>;
    /// `v ~ dist` for a discrete parameter (never an HMC coordinate; used
    /// by prior sampling and Gibbs).
    fn assume_int(&mut self, vn: VarName, dist: &DiscreteDist<T>) -> i64;

    /// `obs ~ dist` for a continuous observation.
    fn observe(&mut self, dist: &ScalarDist<T>, obs: f64);
    /// `obs ~ dist` for a discrete observation.
    fn observe_int(&mut self, dist: &DiscreteDist<T>, obs: i64);
    /// `obs ~ dist` for a vector observation.
    fn observe_vec(&mut self, dist: &VecDist<T>, obs: &[f64]);

    /// Add a raw likelihood-side term (custom densities, marginalized
    /// mixtures — the `@logpdf` escape hatch).
    fn add_obs_logp(&mut self, lp: T);
    /// Add a raw prior-side term.
    fn add_prior_logp(&mut self, lp: T);

    /// Early rejection: pin log-density at −∞.
    fn reject(&mut self);
    /// Whether this run has been rejected.
    fn rejected(&self) -> bool;

    /// The execution context (models may inspect e.g. minibatch scale).
    fn context(&self) -> Context;

    /// Skip `n` observation sites without scoring them. Window-aware
    /// model bodies (tall-data models) call this to jump over
    /// out-of-window likelihood blocks without evaluating them — the
    /// sites still count toward the context's observation indices, so
    /// `Context::Subsample`/`ObsWindow` semantics stay identical to a
    /// body that visits every site. Executors that do not count
    /// observation sites may ignore it.
    fn skip_obs(&mut self, n: usize) {
        let _ = n;
    }

    /// iid continuous observations under one distribution.
    fn observe_iid(&mut self, dist: &ScalarDist<T>, obs: &[f64]) {
        for &o in obs {
            if self.rejected() {
                return;
            }
            self.observe(dist, o);
        }
    }

    /// iid discrete observations under one distribution.
    fn observe_int_iid(&mut self, dist: &DiscreteDist<T>, obs: &[i64]) {
        for &o in obs {
            if self.rejected() {
                return;
            }
            self.observe_int(dist, o);
        }
    }
}

/// A probabilistic model: data plus a generative body.
///
/// Implementations are usually produced by the [`crate::model!`] macro,
/// which writes the body once (generic over [`Scalar`]) and dispatches the
/// four monomorphizations here.
pub trait Model: Send + Sync {
    fn name(&self) -> &str;
    /// Evaluate with plain floats (sampling, cheap log-density).
    fn eval_f64(&self, api: &mut dyn TildeApi<f64>);
    /// Evaluate with forward-mode duals.
    fn eval_dual(&self, api: &mut dyn TildeApi<Dual>);
    /// Evaluate with reverse-tape variables.
    fn eval_tape(&self, api: &mut dyn TildeApi<TVar>);
    /// Evaluate with arena-fused reverse variables (the Stan-style native
    /// gradient fast path; see [`crate::ad::arena`]).
    fn eval_arena(&self, api: &mut dyn TildeApi<AVar>);
    /// Evaluate with K-lane batched arena variables: one tilde walk scores
    /// K chains / particles / ELBO draws at once (see [`crate::ad::batch`]
    /// and [`batched`]).
    fn eval_batch(&self, api: &mut dyn TildeApi<crate::ad::batch::BVar>);
    /// Evaluate with structure-recording variables: one walk captures the
    /// tilde sequence and glue arithmetic as a flat opcode program (see
    /// [`crate::ad::record`] and [`compiled`]).
    fn eval_record(&self, api: &mut dyn TildeApi<crate::ad::record::RVar>);
}

/// Run the model under a [`executors::SampleExecutor`], drawing any missing
/// variables from their priors, and return the accumulated log-joint.
pub fn sample_run<R: rand_core::RngCore>(
    model: &dyn Model,
    rng: &mut R,
    vi: &mut crate::varinfo::UntypedVarInfo,
    ctx: Context,
) -> f64 {
    let mut exec = executors::SampleExecutor::new(rng, vi, ctx);
    model.eval_f64(&mut exec);
    let lp = exec.logp();
    vi.logp = lp;
    lp
}

/// Build a fresh trace from the model's prior (first contact): the
/// "initial sampling phase with UntypedVarInfo" of §2.2.
pub fn init_trace<R: rand_core::RngCore>(
    model: &dyn Model,
    rng: &mut R,
) -> crate::varinfo::UntypedVarInfo {
    let mut vi = crate::varinfo::UntypedVarInfo::new();
    let _ = sample_run(model, rng, &mut vi, Context::Default);
    vi
}

/// Specialize: run once untyped, then freeze into a [`crate::varinfo::TypedVarInfo`].
pub fn init_typed<R: rand_core::RngCore>(
    model: &dyn Model,
    rng: &mut R,
) -> crate::varinfo::TypedVarInfo {
    let vi = init_trace(model, rng);
    crate::varinfo::TypedVarInfo::from_untyped(&vi)
}

/// Count the model's observation sites (one plain evaluation over the
/// typed layout at its stored unconstrained point). This is the `N` of a
/// tall-data likelihood — what `Context::Subsample` windows index into.
pub fn count_obs_sites(model: &dyn Model, tvi: &crate::varinfo::TypedVarInfo) -> usize {
    let mut exec =
        executors::TypedExecutor::<f64>::new(tvi, &tvi.unconstrained, Context::Default);
    model.eval_f64(&mut exec);
    exec.obs_count()
}

/// Log-density (+ optionally gradient) of the model at unconstrained θ
/// through the **typed** layout. `T = f64` gives plain evaluation.
pub fn typed_logp(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> f64 {
    metrics::inc(Counter::LogpEvals);
    let mut exec = executors::TypedExecutor::<f64>::new(tvi, theta, ctx);
    model.eval_f64(&mut exec);
    exec.logp()
}

/// Log-density through the typed layout on the **fused** arithmetic
/// family (`TypedFusedExecutor` with the analytic `logpdf_adj` kernels),
/// skipping the backward sweep. Bitwise equal to the value side of
/// [`typed_grad_fused_into`] — and therefore to a compiled
/// [`compiled::StaticProgram`] replay wherever one validated — which is
/// what full-joint consumers (Gibbs, SMC trace scoring) need when they
/// mix plain evaluations with compiled ones inside a single run.
pub fn typed_logp_fused(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> f64 {
    metrics::inc(Counter::LogpEvals);
    crate::ad::arena::begin(theta.len());
    let mut exec = executors::TypedFusedExecutor::new(tvi, theta, ctx);
    model.eval_arena(&mut exec);
    let (lp, _stmts) = exec.finish();
    if !lp.is_finite() {
        metrics::inc(Counter::RejectedEvals);
    }
    lp
}

/// Gradient via forward duals through the typed layout (n passes).
pub fn typed_grad_forward(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> (f64, Vec<f64>) {
    metrics::inc(Counter::GradEvals);
    crate::ad::forward::grad_forward(
        |duals| {
            let mut exec = executors::TypedExecutor::<Dual>::new_generic(tvi, duals, ctx);
            model.eval_dual(&mut exec);
            exec.logp_t()
        },
        theta,
    )
}

/// Arena-fused gradient through the typed layout, written into a
/// caller-owned buffer — the allocation-free `logp_grad_into` hot path of
/// HMC/NUTS leapfrog loops. One pass; density statements contribute
/// analytic-adjoint seeds instead of per-op tape nodes. A rejected or
/// non-finite evaluation zeroes `grad` and returns the (−∞/NaN) value.
pub fn typed_grad_fused_into(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
    grad: &mut [f64],
) -> f64 {
    metrics::inc(Counter::GradEvals);
    crate::ad::arena::begin(theta.len());
    let mut exec = executors::TypedFusedExecutor::new(tvi, theta, ctx);
    model.eval_arena(&mut exec);
    let (lp, stmts) = exec.finish();
    if !lp.is_finite() {
        metrics::inc(Counter::RejectedEvals);
        grad.fill(0.0);
        return lp;
    }
    crate::ad::arena::backward_into(grad, stmts);
    lp
}

/// Allocating convenience wrapper over [`typed_grad_fused_into`].
pub fn typed_grad_fused(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> (f64, Vec<f64>) {
    let mut grad = vec![0.0; theta.len()];
    let lp = typed_grad_fused_into(model, tvi, theta, ctx, &mut grad);
    (lp, grad)
}

/// [`typed_grad_fused_into`] with a per-slot site mask — the Gibbs
/// conditional-density gradient. `mask[si] == false` holds slot `si`'s
/// value fixed: the site still contributes its exact log-density (the
/// returned value is the full joint, bitwise equal to the unmasked
/// pass), but its own coordinates enter the tape as constants, so the
/// site and any glue downstream of it emit **zero** arena nodes and the
/// backward sweep only touches the in-block subgraph. Masked sites still
/// seed their parameter partials — an out-of-block density may depend on
/// in-block variables through its parameters. Gradient entries for
/// masked coordinates come back 0.
pub fn typed_grad_fused_masked_into(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
    mask: &[bool],
    grad: &mut [f64],
) -> f64 {
    metrics::inc(Counter::GradEvals);
    crate::ad::arena::begin(theta.len());
    let mut exec = executors::TypedFusedExecutor::new_masked(tvi, theta, ctx, mask);
    model.eval_arena(&mut exec);
    let (lp, stmts) = exec.finish();
    if !lp.is_finite() {
        metrics::inc(Counter::RejectedEvals);
        grad.fill(0.0);
        return lp;
    }
    crate::ad::arena::backward_into(grad, stmts);
    lp
}

/// Gradient via the reverse tape through the typed layout (one pass).
pub fn typed_grad_reverse(
    model: &dyn Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> (f64, Vec<f64>) {
    metrics::inc(Counter::GradEvals);
    crate::ad::reverse::grad_reverse(
        |tvars| {
            let mut exec = executors::TypedExecutor::<TVar>::new_generic(tvi, tvars, ctx);
            model.eval_tape(&mut exec);
            exec.logp_t()
        },
        theta,
    )
}

/// Log-density at unconstrained θ through the **untyped** (boxed, hashed)
/// trace — the pre-specialization path.
pub fn untyped_logp(
    model: &dyn Model,
    vi: &crate::varinfo::UntypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> f64 {
    metrics::inc(Counter::LogpEvals);
    let mut exec = executors::UntypedFlatExecutor::<f64>::new(vi, theta, ctx);
    model.eval_f64(&mut exec);
    exec.logp()
}

/// Forward-mode gradient through the untyped trace.
pub fn untyped_grad_forward(
    model: &dyn Model,
    vi: &crate::varinfo::UntypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> (f64, Vec<f64>) {
    metrics::inc(Counter::GradEvals);
    crate::ad::forward::grad_forward(
        |duals| {
            let mut exec = executors::UntypedFlatExecutor::<Dual>::new_generic(vi, duals, ctx);
            model.eval_dual(&mut exec);
            exec.logp_t()
        },
        theta,
    )
}

/// Arena-fused gradient through the untyped (boxed, hashed) trace into a
/// caller-owned buffer: dynamic trace addressing, fused density kernels.
pub fn untyped_grad_fused_into(
    model: &dyn Model,
    vi: &crate::varinfo::UntypedVarInfo,
    theta: &[f64],
    ctx: Context,
    grad: &mut [f64],
) -> f64 {
    metrics::inc(Counter::GradEvals);
    crate::ad::arena::begin(theta.len());
    let mut exec = executors::UntypedFusedExecutor::new(vi, theta, ctx);
    model.eval_arena(&mut exec);
    let (lp, stmts) = exec.finish();
    if !lp.is_finite() {
        metrics::inc(Counter::RejectedEvals);
        grad.fill(0.0);
        return lp;
    }
    crate::ad::arena::backward_into(grad, stmts);
    lp
}

/// Allocating convenience wrapper over [`untyped_grad_fused_into`].
pub fn untyped_grad_fused(
    model: &dyn Model,
    vi: &crate::varinfo::UntypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> (f64, Vec<f64>) {
    let mut grad = vec![0.0; theta.len()];
    let lp = untyped_grad_fused_into(model, vi, theta, ctx, &mut grad);
    (lp, grad)
}

/// Reverse-tape gradient through the untyped trace.
pub fn untyped_grad_reverse(
    model: &dyn Model,
    vi: &crate::varinfo::UntypedVarInfo,
    theta: &[f64],
    ctx: Context,
) -> (f64, Vec<f64>) {
    metrics::inc(Counter::GradEvals);
    crate::ad::reverse::grad_reverse(
        |tvars| {
            let mut exec = executors::UntypedFlatExecutor::<TVar>::new_generic(vi, tvars, ctx);
            model.eval_tape(&mut exec);
            exec.logp_t()
        },
        theta,
    )
}
