//! Warmup adaptation: dual-averaging step-size (Nesterov 2009, as used by
//! Stan and AdvancedHMC) and diagonal mass-matrix estimation (Welford).

/// Dual-averaging step-size adaptation targeting an acceptance statistic.
#[derive(Clone, Debug)]
pub struct DualAveraging {
    pub target_accept: f64,
    mu: f64,
    log_eps: f64,
    log_eps_bar: f64,
    h_bar: f64,
    t: u64,
    gamma: f64,
    t0: f64,
    kappa: f64,
}

impl DualAveraging {
    pub fn new(eps0: f64, target_accept: f64) -> Self {
        Self {
            target_accept,
            mu: (10.0 * eps0).ln(),
            log_eps: eps0.ln(),
            log_eps_bar: 0.0,
            h_bar: 0.0,
            t: 0,
            gamma: 0.05,
            t0: 10.0,
            kappa: 0.75,
        }
    }

    /// Update with the iteration's acceptance probability; returns the new
    /// step size to use next iteration.
    pub fn update(&mut self, accept_prob: f64) -> f64 {
        self.t += 1;
        let t = self.t as f64;
        let eta = 1.0 / (t + self.t0);
        self.h_bar = (1.0 - eta) * self.h_bar + eta * (self.target_accept - accept_prob);
        self.log_eps = self.mu - t.sqrt() / self.gamma * self.h_bar;
        let x_eta = t.powf(-self.kappa);
        self.log_eps_bar = x_eta * self.log_eps + (1.0 - x_eta) * self.log_eps_bar;
        self.log_eps.exp()
    }

    /// Current (adapting) step size.
    pub fn current(&self) -> f64 {
        self.log_eps.exp()
    }

    /// Smoothed step size to freeze after warmup.
    pub fn finalized(&self) -> f64 {
        self.log_eps_bar.exp()
    }
}

/// Streaming diagonal (co)variance estimator for mass-matrix adaptation.
#[derive(Clone, Debug)]
pub struct WelfordVar {
    n: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl WelfordVar {
    pub fn new(dim: usize) -> Self {
        Self {
            n: 0,
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
        }
    }

    pub fn push(&mut self, x: &[f64]) {
        self.n += 1;
        let n = self.n as f64;
        for i in 0..x.len() {
            let d = x[i] - self.mean[i];
            self.mean[i] += d / n;
            self.m2[i] += d * (x[i] - self.mean[i]);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Regularized variance estimate (Stan's shrinkage toward unit).
    pub fn variance(&self) -> Vec<f64> {
        let n = self.n as f64;
        if self.n < 2 {
            return vec![1.0; self.mean.len()];
        }
        let w = n / (n + 5.0);
        self.m2
            .iter()
            .map(|&m2| (w * m2 / (n - 1.0) + (1.0 - w) * 1e-3).max(1e-10))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_averaging_raises_eps_when_overaccepting() {
        let mut da = DualAveraging::new(0.1, 0.8);
        for _ in 0..100 {
            da.update(1.0); // always accepting → step too small
        }
        assert!(da.finalized() > 0.1);
    }

    #[test]
    fn dual_averaging_lowers_eps_when_rejecting() {
        let mut da = DualAveraging::new(0.1, 0.8);
        for _ in 0..100 {
            da.update(0.0);
        }
        assert!(da.finalized() < 0.1);
    }

    #[test]
    fn dual_averaging_converges_near_target() {
        // Toy response: accept prob decreases with eps as exp(-eps).
        let mut da = DualAveraging::new(1.0, 0.65);
        let mut eps: f64 = 1.0;
        for _ in 0..2000 {
            let acc = (-eps).exp();
            eps = da.update(acc);
        }
        let fin = da.finalized();
        assert!(
            ((-fin).exp() - 0.65).abs() < 0.05,
            "converged eps {fin} gives accept {}",
            (-fin).exp()
        );
    }

    #[test]
    fn welford_variance() {
        let mut w = WelfordVar::new(2);
        // stream with var [4, 0.25]
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(5);
        use crate::util::rng::Rng;
        for _ in 0..20000 {
            w.push(&[2.0 * rng.normal(), 0.5 * rng.normal() + 3.0]);
        }
        let v = w.variance();
        assert!((v[0] - 4.0).abs() < 0.3, "{v:?}");
        assert!((v[1] - 0.25).abs() < 0.05, "{v:?}");
    }

    #[test]
    fn welford_regularizes_small_samples() {
        let w = WelfordVar::new(3);
        assert_eq!(w.variance(), vec![1.0, 1.0, 1.0]);
    }
}
