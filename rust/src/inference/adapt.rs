//! Warmup adaptation: dual-averaging step-size (Nesterov 2009, as used by
//! Stan and AdvancedHMC), diagonal mass-matrix estimation (Welford), and
//! Stan's initial-step-size doubling heuristic.

use rand_core::RngCore;

use crate::gradient::LogDensity;
use crate::util::rng::Rng;

/// Stan's initial-step-size heuristic (Hoffman & Gelman 2014, Alg. 4 with
/// identity mass): from a random momentum, take **one** leapfrog step and
/// double/halve ε until the step's acceptance probability crosses ½.
/// Runs entirely on the allocation-free [`LogDensity::logp_grad_into`]
/// path — two reused buffers, however many probes it takes.
///
/// Returns `(ε, gradient evaluations spent)` so callers can keep their
/// `n_grad_evals` accounting honest. Self-contained by design: it
/// evaluates its own base gradient at `theta0` (one evaluation the
/// calling sampler will repeat), which keeps it usable standalone.
pub fn find_initial_step_size<R: RngCore>(
    ld: &dyn LogDensity,
    theta0: &[f64],
    eps0: f64,
    rng: &mut R,
) -> (f64, u64) {
    let dim = ld.dim();
    let mut eps = if eps0.is_finite() && eps0 > 0.0 {
        eps0
    } else {
        1.0
    };
    let mut n_evals: u64 = 1;
    let mut grad0 = vec![0.0; dim];
    let lp0 = ld.logp_grad_into(theta0, &mut grad0);
    if !lp0.is_finite() || dim == 0 {
        return (eps, n_evals);
    }
    let p0: Vec<f64> = (0..dim).map(|_| rng.normal()).collect();
    let h0 = -lp0 + 0.5 * p0.iter().map(|x| x * x).sum::<f64>();

    // scratch reused across probes
    let mut theta = vec![0.0; dim];
    let mut p = vec![0.0; dim];
    let mut grad = vec![0.0; dim];

    let mut log_ratio = |eps: f64, n_evals: &mut u64| -> f64 {
        *n_evals += 1;
        theta.copy_from_slice(theta0);
        p.copy_from_slice(&p0);
        for i in 0..dim {
            p[i] += 0.5 * eps * grad0[i];
            theta[i] += eps * p[i];
        }
        let lp = ld.logp_grad_into(&theta, &mut grad);
        if !lp.is_finite() {
            return f64::NEG_INFINITY;
        }
        for i in 0..dim {
            p[i] += 0.5 * eps * grad[i];
        }
        h0 - (-lp + 0.5 * p.iter().map(|x| x * x).sum::<f64>())
    };

    // direction: double while accept > 1/2, else halve while accept < 1/2
    let half_ln = (0.5f64).ln();
    let mut r = log_ratio(eps, &mut n_evals);
    let dir: f64 = if r > half_ln { 1.0 } else { -1.0 };
    for _ in 0..50 {
        if (dir > 0.0 && r <= half_ln) || (dir < 0.0 && r >= half_ln) {
            break;
        }
        eps *= if dir > 0.0 { 2.0 } else { 0.5 };
        if !(1e-10..=1e10).contains(&eps) {
            // a degenerate target ran the doubling past the guard rail:
            // hand dual averaging the rail, not the overshoot
            eps = eps.clamp(1e-10, 1e10);
            break;
        }
        r = log_ratio(eps, &mut n_evals);
    }
    (eps, n_evals)
}

/// Dual-averaging step-size adaptation targeting an acceptance statistic.
#[derive(Clone, Debug)]
pub struct DualAveraging {
    pub target_accept: f64,
    mu: f64,
    log_eps: f64,
    log_eps_bar: f64,
    h_bar: f64,
    t: u64,
    gamma: f64,
    t0: f64,
    kappa: f64,
}

impl DualAveraging {
    pub fn new(eps0: f64, target_accept: f64) -> Self {
        Self {
            target_accept,
            mu: (10.0 * eps0).ln(),
            log_eps: eps0.ln(),
            log_eps_bar: 0.0,
            h_bar: 0.0,
            t: 0,
            gamma: 0.05,
            t0: 10.0,
            kappa: 0.75,
        }
    }

    /// Update with the iteration's acceptance probability; returns the new
    /// step size to use next iteration.
    pub fn update(&mut self, accept_prob: f64) -> f64 {
        self.t += 1;
        let t = self.t as f64;
        let eta = 1.0 / (t + self.t0);
        self.h_bar = (1.0 - eta) * self.h_bar + eta * (self.target_accept - accept_prob);
        self.log_eps = self.mu - t.sqrt() / self.gamma * self.h_bar;
        let x_eta = t.powf(-self.kappa);
        self.log_eps_bar = x_eta * self.log_eps + (1.0 - x_eta) * self.log_eps_bar;
        self.log_eps.exp()
    }

    /// Current (adapting) step size.
    pub fn current(&self) -> f64 {
        self.log_eps.exp()
    }

    /// Smoothed step size to freeze after warmup.
    pub fn finalized(&self) -> f64 {
        self.log_eps_bar.exp()
    }
}

/// Streaming diagonal (co)variance estimator for mass-matrix adaptation.
#[derive(Clone, Debug)]
pub struct WelfordVar {
    n: u64,
    mean: Vec<f64>,
    m2: Vec<f64>,
}

impl WelfordVar {
    pub fn new(dim: usize) -> Self {
        Self {
            n: 0,
            mean: vec![0.0; dim],
            m2: vec![0.0; dim],
        }
    }

    pub fn push(&mut self, x: &[f64]) {
        self.n += 1;
        let n = self.n as f64;
        for i in 0..x.len() {
            let d = x[i] - self.mean[i];
            self.mean[i] += d / n;
            self.m2[i] += d * (x[i] - self.mean[i]);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    /// Regularized variance estimate (Stan's shrinkage toward unit).
    pub fn variance(&self) -> Vec<f64> {
        let n = self.n as f64;
        if self.n < 2 {
            return vec![1.0; self.mean.len()];
        }
        let w = n / (n + 5.0);
        self.m2
            .iter()
            .map(|&m2| (w * m2 / (n - 1.0) + (1.0 - w) * 1e-3).max(1e-10))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dual_averaging_raises_eps_when_overaccepting() {
        let mut da = DualAveraging::new(0.1, 0.8);
        for _ in 0..100 {
            da.update(1.0); // always accepting → step too small
        }
        assert!(da.finalized() > 0.1);
    }

    #[test]
    fn dual_averaging_lowers_eps_when_rejecting() {
        let mut da = DualAveraging::new(0.1, 0.8);
        for _ in 0..100 {
            da.update(0.0);
        }
        assert!(da.finalized() < 0.1);
    }

    #[test]
    fn dual_averaging_converges_near_target() {
        // Toy response: accept prob decreases with eps as exp(-eps).
        let mut da = DualAveraging::new(1.0, 0.65);
        let mut eps: f64 = 1.0;
        for _ in 0..2000 {
            let acc = (-eps).exp();
            eps = da.update(acc);
        }
        let fin = da.finalized();
        assert!(
            ((-fin).exp() - 0.65).abs() < 0.05,
            "converged eps {fin} gives accept {}",
            (-fin).exp()
        );
    }

    #[test]
    fn welford_variance() {
        let mut w = WelfordVar::new(2);
        // stream with var [4, 0.25]
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(5);
        use crate::util::rng::Rng;
        for _ in 0..20000 {
            w.push(&[2.0 * rng.normal(), 0.5 * rng.normal() + 3.0]);
        }
        let v = w.variance();
        assert!((v[0] - 4.0).abs() < 0.3, "{v:?}");
        assert!((v[1] - 0.25).abs() < 0.05, "{v:?}");
    }

    #[test]
    fn welford_regularizes_small_samples() {
        let w = WelfordVar::new(3);
        assert_eq!(w.variance(), vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn initial_step_size_lands_near_target_scale() {
        // Std normal: the heuristic's fixed point is ε where a single
        // leapfrog step has accept ≈ 1/2, which for N(0, I) is O(1).
        let ld = crate::gradient::std_normal_density(5);
        let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(3);
        let theta0 = [0.3, -0.2, 0.1, 0.0, 0.4];
        // far-too-small and far-too-large guesses both converge to O(1)
        let (lo, lo_evals) = find_initial_step_size(&ld, &theta0, 1e-6, &mut rng);
        let (hi, _) = find_initial_step_size(&ld, &theta0, 1e4, &mut rng);
        assert!(lo > 1e-3 && lo < 100.0, "{lo}");
        assert!(hi > 1e-3 && hi < 1e4, "{hi}");
        // the probe reports its gradient spend (init eval + ≥1 probe)
        assert!(lo_evals >= 2, "{lo_evals}");
        // a tight target (tiny variance) forces a small ε
        let stiff = crate::gradient::FnDensity {
            dim: 1,
            f: |t: &[f64]| -0.5 * t[0] * t[0] / 1e-6,
            g: |t: &[f64]| (-0.5 * t[0] * t[0] / 1e-6, vec![-t[0] / 1e-6]),
        };
        let (eps, _) = find_initial_step_size(&stiff, &[0.0], 1.0, &mut rng);
        assert!(eps < 0.1, "{eps}");
    }
}
