//! Blocked Gibbs sampling over the typed trace.
//!
//! Each [`GibbsBlock`] owns a subset of `VarName`s and a within-block
//! sampler; one Gibbs sweep updates every block from its full conditional
//! (∝ the joint, with the other blocks held fixed). Discrete variables are
//! updated by exact enumeration of their support — the combination
//! (HMC-within-Gibbs over continuous blocks + enumeration of discrete ones)
//! is the Turing idiom the paper's §3.2 mentions ("HMC within Gibbs").

use rand_core::RngCore;

use crate::chain::SamplerStats;
use crate::context::Context;
use crate::dist::{bijector, Domain};
use crate::model::{
    compiled, init_trace, typed_grad_forward, typed_grad_fused_masked_into, typed_grad_reverse,
    typed_logp, typed_logp_fused, Model,
};
use crate::obs::metrics::{self, Counter};
use crate::particle::Resampler;
use crate::util::rng::Rng;
use crate::value::Value;
use crate::varinfo::TypedVarInfo;
use crate::varname::VarName;

/// Within-block sampler.
#[derive(Clone, Debug)]
pub enum BlockSampler {
    /// Random-walk MH on the block's unconstrained coordinates.
    RwMh { scale: f64 },
    /// Static HMC on the block (other coordinates' gradient masked).
    Hmc { step_size: f64, n_leapfrog: usize },
    /// Exact enumeration (categorical/bool supports only).
    Enumerate,
    /// Conditional SMC (Particle-Gibbs): the block is updated by an
    /// N-particle filter pinned to the current trajectory
    /// ([`crate::inference::smc::csmc_sweep`]). Works for continuous,
    /// discrete and mixed blocks — the particle analogue of "HMC within
    /// Gibbs", and the only block sampler that handles unbounded discrete
    /// supports. Sweeps run on the typed fast path (the sampler's
    /// `TypedVarInfo` doubles as the particle template) and demote to the
    /// boxed replay automatically on dynamic structure changes.
    ParticleGibbs {
        n_particles: usize,
        resampler: Resampler,
        /// Ancestor sampling (PGAS): also resample the retained particle's
        /// ancestor at each resampling step — much better path-space
        /// mixing on long sequential blocks, at ~one extra evaluation
        /// replay per particle per resampling step.
        ancestor_sampling: bool,
    },
}

/// One Gibbs block: which variables it owns + how it updates them.
#[derive(Clone, Debug)]
pub struct GibbsBlock {
    pub vars: Vec<VarName>,
    pub sampler: BlockSampler,
}

impl GibbsBlock {
    pub fn rwmh(vars: &[&str], scale: f64) -> Self {
        Self {
            vars: vars.iter().map(|v| VarName::new(v)).collect(),
            sampler: BlockSampler::RwMh { scale },
        }
    }

    pub fn hmc(vars: &[&str], step_size: f64, n_leapfrog: usize) -> Self {
        Self {
            vars: vars.iter().map(|v| VarName::new(v)).collect(),
            sampler: BlockSampler::Hmc {
                step_size,
                n_leapfrog,
            },
        }
    }

    pub fn enumerate(vars: &[&str]) -> Self {
        Self {
            vars: vars.iter().map(|v| VarName::new(v)).collect(),
            sampler: BlockSampler::Enumerate,
        }
    }

    /// Particle-Gibbs block (multinomial resampling — the safe scheme for
    /// the conditional filter).
    pub fn particle_gibbs(vars: &[&str], n_particles: usize) -> Self {
        Self {
            vars: vars.iter().map(|v| VarName::new(v)).collect(),
            sampler: BlockSampler::ParticleGibbs {
                n_particles,
                resampler: Resampler::Multinomial,
                ancestor_sampling: false,
            },
        }
    }

    /// Particle-Gibbs block with ancestor sampling (PGAS) — use for long
    /// sequential blocks (state-space latents) where the plain conditional
    /// filter's path degeneracy freezes the early trajectory.
    pub fn particle_gibbs_as(vars: &[&str], n_particles: usize) -> Self {
        Self {
            vars: vars.iter().map(|v| VarName::new(v)).collect(),
            sampler: BlockSampler::ParticleGibbs {
                n_particles,
                resampler: Resampler::Multinomial,
                ancestor_sampling: true,
            },
        }
    }
}

/// AD backend for HMC blocks.
#[derive(Clone, Copy, Debug)]
pub enum GibbsGrad {
    Forward,
    Reverse,
    /// Arena-fused reverse mode (`Backend::ReverseFused`) with the
    /// block's conditional density masked at kernel-emission time:
    /// out-of-block sites still contribute their exact full-joint lp,
    /// but their values enter the tape as constants, so they (and glue
    /// downstream of them) cost zero arena nodes. In-block gradient
    /// entries are bitwise equal to the unmasked fused gradient.
    Fused,
}

/// Blocked Gibbs sampler.
#[derive(Clone, Debug)]
pub struct Gibbs {
    pub blocks: Vec<GibbsBlock>,
    pub grad: GibbsGrad,
    /// Rao-Blackwellization switch: when `true` (the [`Gibbs::new`]
    /// default), the static analyzer runs once up front and every
    /// [`BlockSampler::RwMh`] block whose slots all carry a
    /// [conjugacy certificate](crate::analysis::ConjugacyCert) is upgraded
    /// to exact closed-form full-conditional draws — no proposals, no
    /// rejections. Blocks that do not fully certify keep their configured
    /// sampler, so mixing conjugate and generic blocks is free.
    pub collapse: bool,
}

/// Gibbs output: constrained rows (continuous + discrete, in
/// `TypedVarInfo::row` order) plus per-sweep log-density.
#[derive(Clone, Debug)]
pub struct GibbsDraws {
    pub rows: Vec<Vec<f64>>,
    pub logps: Vec<f64>,
    pub stats: SamplerStats,
}

impl Gibbs {
    pub fn new(blocks: Vec<GibbsBlock>) -> Self {
        Self {
            blocks,
            grad: GibbsGrad::Forward,
            collapse: true,
        }
    }

    pub fn sample<R: RngCore>(
        &self,
        model: &dyn Model,
        tvi0: &TypedVarInfo,
        warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> GibbsDraws {
        let t_start = std::time::Instant::now();
        let mut tvi = tvi0.clone();
        let mut theta = tvi.unconstrained.clone();
        // Full-joint evaluations ride the compiled static replay when the
        // model proves structurally stable (one compile per run). The
        // discrete-trace gate matters here: enumeration blocks mutate
        // `tvi.discrete` mid-sweep, and any value off the compile-time
        // snapshot demotes — to the *fused* dynamic walk, the arithmetic
        // family the compiled program is bitwise-validated against, so a
        // sweep never mixes lp families. Models that do not promote keep
        // the historical plain-walk evaluation.
        let prog = compiled::try_compile(model, &tvi);
        let joint_lp = |tvi: &TypedVarInfo, theta: &[f64]| -> f64 {
            match &prog {
                Some(p) if p.matches_discrete(tvi) => p.logp(tvi, theta, Context::Default),
                Some(_) => {
                    metrics::inc(Counter::StaticDemotions);
                    typed_logp_fused(model, tvi, theta, Context::Default)
                }
                None => typed_logp(model, tvi, theta, Context::Default),
            }
        };
        let mut lp = joint_lp(&tvi, &theta);
        assert!(lp.is_finite(), "Gibbs initialized at zero-probability point");

        // Resolve blocks to coordinate index sets / discrete slots.
        let mut cont_blocks: Vec<(usize, Vec<usize>, Vec<bool>)> = Vec::new(); // (block idx, θ coords, slot mask)
        let mut disc_blocks: Vec<(usize, Vec<usize>)> = Vec::new(); // (block idx, slot idx)
        let mut pg_blocks: Vec<(usize, Vec<usize>)> = Vec::new(); // (block idx, slot idx)
        for (bi, block) in self.blocks.iter().enumerate() {
            let mut coords = Vec::new();
            let mut slots = Vec::new();
            let mut all_slots = Vec::new();
            for (si, slot) in tvi.slots().iter().enumerate() {
                if block.vars.iter().any(|v| slot.vn.subsumed_by(v)) {
                    all_slots.push(si);
                    if slot.domain.is_discrete() {
                        slots.push(si);
                    } else {
                        coords.extend(slot.unc_offset..slot.unc_offset + slot.unc_len);
                    }
                }
            }
            assert!(
                !(coords.is_empty() && slots.is_empty()),
                "Gibbs block {bi} matches no variables"
            );
            match block.sampler {
                BlockSampler::Enumerate => {
                    assert!(coords.is_empty(), "Enumerate block over continuous vars");
                    disc_blocks.push((bi, slots));
                }
                // Particle-Gibbs owns continuous *and* discrete slots
                BlockSampler::ParticleGibbs { .. } => pg_blocks.push((bi, all_slots)),
                _ => {
                    assert!(slots.is_empty(), "continuous sampler over discrete vars");
                    // per-slot mask for the fused conditional gradient:
                    // `true` = in this block (tracked on the tape)
                    let mut mask = vec![false; tvi.slots().len()];
                    for &si in &all_slots {
                        mask[si] = true;
                    }
                    cont_blocks.push((bi, coords, mask));
                }
            }
        }

        // Rao-Blackwellization: run the static analyzer once and mark
        // every RwMh block whose slots all carry a conjugacy certificate.
        // For those blocks the MH proposal loop below is replaced by exact
        // closed-form full-conditional draws (certificate indices, in slot
        // order — a valid systematic Gibbs scan within the block).
        let analysis = if self.collapse
            && cont_blocks
                .iter()
                .any(|(bi, ..)| matches!(self.blocks[*bi].sampler, BlockSampler::RwMh { .. }))
        {
            crate::analysis::analyze(model, &tvi)
        } else {
            None
        };
        let conj_blocks: Vec<Option<Vec<usize>>> = cont_blocks
            .iter()
            .map(|(bi, _, mask)| {
                let a = analysis.as_ref()?;
                if !matches!(self.blocks[*bi].sampler, BlockSampler::RwMh { .. }) {
                    return None;
                }
                let mut certs = Vec::new();
                for (si, &in_block) in mask.iter().enumerate() {
                    if in_block {
                        certs.push(a.certs.iter().position(|c| c.slot == si)?);
                    }
                }
                Some(certs)
            })
            .collect();

        // Particle-Gibbs blocks replay the model through a boxed trace
        // template that mirrors the typed layout (one record per slot);
        // the observe-statement count is a model constant — probe once.
        let mut pg_vi = if pg_blocks.is_empty() {
            None
        } else {
            let vi = init_trace(model, rng);
            assert!(
                tvi.layout_matches(&vi),
                "Particle-Gibbs requires a trace layout matching the model"
            );
            Some(vi)
        };
        let pg_n_obs = pg_vi
            .as_ref()
            .map(|vi| crate::particle::count_observes(model, vi));

        let mut rows = Vec::with_capacity(iters);
        let mut logps = Vec::with_capacity(iters);
        let mut accepts = 0.0;
        let mut proposals = 0.0;
        let mut n_grad = 0u64;
        let mut warmup_secs = 0.0;

        for it in 0..warmup + iters {
            // continuous blocks
            for ((bi, coords, mask), conj) in cont_blocks.iter().zip(&conj_blocks) {
                if let Some(cert_ids) = conj {
                    // conjugate block: exact draws from the closed-form
                    // full conditionals — always "accepted"
                    let a = analysis.as_ref().expect("certificates imply analysis");
                    for &ci in cert_ids {
                        a.draw_conjugate(&a.certs[ci], &tvi, &mut theta, rng);
                    }
                    lp = joint_lp(&tvi, &theta);
                    proposals += 1.0;
                    accepts += 1.0;
                    continue;
                }
                match self.blocks[*bi].sampler {
                    BlockSampler::RwMh { scale } => {
                        let mut prop = theta.clone();
                        for &c in coords {
                            prop[c] += scale * rng.normal();
                        }
                        let lp_prop = joint_lp(&tvi, &prop);
                        proposals += 1.0;
                        if lp_prop.is_finite() && rng.uniform_pos().ln() < lp_prop - lp {
                            theta = prop;
                            lp = lp_prop;
                            accepts += 1.0;
                        }
                    }
                    BlockSampler::Hmc {
                        step_size,
                        n_leapfrog,
                    } => {
                        let grad_fn = |th: &[f64]| -> (f64, Vec<f64>) {
                            match self.grad {
                                GibbsGrad::Forward => {
                                    typed_grad_forward(model, &tvi, th, Context::Default)
                                }
                                GibbsGrad::Reverse => {
                                    typed_grad_reverse(model, &tvi, th, Context::Default)
                                }
                                // full-joint fused kernels with out-of-block
                                // sites masked to constants before emission —
                                // same lp and same in-block gradient entries
                                // as the unmasked pass, near-zero tape for
                                // everything this block does not move
                                GibbsGrad::Fused => {
                                    let mut g = vec![0.0; th.len()];
                                    let lp = typed_grad_fused_masked_into(
                                        model,
                                        &tvi,
                                        th,
                                        Context::Default,
                                        mask,
                                        &mut g,
                                    );
                                    (lp, g)
                                }
                            }
                        };
                        let (lp0, mut grad) = grad_fn(&theta);
                        n_grad += 1;
                        let mut prop = theta.clone();
                        let mut p: Vec<f64> = coords.iter().map(|_| rng.normal()).collect();
                        let ke0: f64 = 0.5 * p.iter().map(|x| x * x).sum::<f64>();
                        let h0 = -lp0 + ke0;
                        let mut lp_prop = lp0;
                        let mut ok = true;
                        for _ in 0..n_leapfrog {
                            for (j, &c) in coords.iter().enumerate() {
                                p[j] += 0.5 * step_size * grad[c];
                                prop[c] += step_size * p[j];
                            }
                            let (l, g) = grad_fn(&prop);
                            n_grad += 1;
                            lp_prop = l;
                            grad = g;
                            if !l.is_finite() {
                                ok = false;
                                break;
                            }
                            for (j, &c) in coords.iter().enumerate() {
                                p[j] += 0.5 * step_size * grad[c];
                            }
                        }
                        proposals += 1.0;
                        if ok {
                            let ke1: f64 = 0.5 * p.iter().map(|x| x * x).sum::<f64>();
                            let h1 = -lp_prop + ke1;
                            if rng.uniform_pos().ln() < h0 - h1 {
                                theta = prop;
                                lp = lp_prop;
                                accepts += 1.0;
                            }
                        }
                    }
                    BlockSampler::Enumerate | BlockSampler::ParticleGibbs { .. } => {
                        unreachable!()
                    }
                }
            }

            // Particle-Gibbs blocks: conditional-SMC sweeps
            for (bi, slots) in &pg_blocks {
                let cfg = match self.blocks[*bi].sampler {
                    BlockSampler::ParticleGibbs {
                        n_particles,
                        resampler,
                        ancestor_sampling,
                    } => crate::inference::smc::Csmc {
                        n_particles,
                        resampler,
                        ess_threshold: 0.5,
                        ancestor_sampling,
                    },
                    _ => unreachable!(),
                };
                let vi = pg_vi.as_mut().expect("pg template exists");
                // sync the current typed state into the replay template
                tvi.set_unconstrained(&theta);
                for slot in tvi.slots() {
                    vi.set_value(&slot.vn, tvi.boxed_value(slot));
                }
                let sweep_seed = rng.next_u64();
                // the sampler's own typed state doubles as the particle
                // template: sweeps run over forked flat buffers and fall
                // back to the boxed replay on dynamic structure changes
                let selected = crate::inference::smc::csmc_sweep(
                    model,
                    vi,
                    &self.blocks[*bi].vars,
                    &cfg,
                    sweep_seed,
                    pg_n_obs,
                    Some(&tvi),
                );
                // write the selected particle's block values back into the
                // typed state (link continuous values, copy discrete ones)
                let mut buf: Vec<f64> = Vec::new();
                for &si in slots {
                    let slot = tvi.slots()[si].clone();
                    let value = selected
                        .get(&slot.vn)
                        .expect("selected trace lost a block variable")
                        .value
                        .clone();
                    if slot.domain.is_discrete() {
                        tvi.discrete[slot.disc_offset] =
                            value.as_int().expect("discrete slot with non-integer value");
                    } else {
                        buf.clear();
                        match &value {
                            Value::F64(x) => bijector::link(&slot.domain, &[*x], &mut buf),
                            Value::Vec(v) => bijector::link(&slot.domain, v, &mut buf),
                            other => panic!("continuous slot with value {other:?}"),
                        }
                        theta[slot.unc_offset..slot.unc_offset + slot.unc_len]
                            .copy_from_slice(&buf);
                    }
                }
                lp = joint_lp(&tvi, &theta);
                proposals += 1.0;
                accepts += 1.0; // CSMC selection always yields a valid draw
            }

            // discrete blocks: exact full-conditional draws
            for (_, slots) in &disc_blocks {
                for &si in slots {
                    let slot = tvi.slots()[si].clone();
                    let support: Vec<i64> = match slot.domain {
                        Domain::DiscreteCategory(k) => (0..k as i64).collect(),
                        Domain::DiscreteBool => vec![0, 1],
                        ref d => panic!("cannot enumerate domain {d:?}"),
                    };
                    let mut logw = Vec::with_capacity(support.len());
                    for &k in &support {
                        tvi.discrete[slot.disc_offset] = k;
                        logw.push(joint_lp(&tvi, &theta));
                    }
                    let z = crate::util::math::log_sum_exp(&logw);
                    let probs: Vec<f64> = logw.iter().map(|&l| (l - z).exp()).collect();
                    let pick = rng.categorical(&probs);
                    tvi.discrete[slot.disc_offset] = support[pick];
                    lp = logw[pick];
                }
            }

            if it >= warmup {
                tvi.set_unconstrained(&theta);
                rows.push(tvi.row());
                logps.push(lp);
            }
            if it + 1 == warmup {
                warmup_secs = t_start.elapsed().as_secs_f64();
            }
        }

        let wall_secs = t_start.elapsed().as_secs_f64();
        GibbsDraws {
            rows,
            logps,
            stats: SamplerStats {
                accept_rate: if proposals > 0.0 {
                    accepts / proposals
                } else {
                    1.0
                },
                divergences: 0,
                step_size: 0.0,
                n_grad_evals: n_grad,
                wall_secs,
                warmup_secs,
                sampling_secs: wall_secs - warmup_secs,
                ..SamplerStats::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_typed;
    use crate::prelude::*;
    use crate::util::stats;

    model! {
        /// Conjugate normal with unknown mean and variance.
        pub GaussUnknown {
            y: Vec<f64>,
        }
        fn body<T>(this, api) {
            let var = tilde!(api, var ~ InverseGamma(c(2.0), c(3.0)));
            let m = tilde!(api, m ~ Normal(c(0.0), (var * 2.0).sqrt()));
            let sd = var.sqrt();
            for &yi in &this.y {
                obs!(api, yi => Normal(m, sd));
            }
        }
    }

    model! {
        /// Two-component mixture with a discrete assignment parameter.
        pub TinyMixture {
            y: f64,
        }
        fn body<T>(this, api) {
            let z = tilde_int!(api, z ~ Bernoulli(c(0.3)));
            let mu = if z == 1 { 3.0 } else { -3.0 };
            obs!(api, this.y => Normal(c(mu), c(1.0)));
        }
    }

    #[test]
    fn gibbs_mixes_continuous_blocks() {
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let y: Vec<f64> = (0..200).map(|_| 1.5 + 0.7 * rng.normal()).collect();
        let m = GaussUnknown { y };
        let tvi = init_typed(&m, &mut rng);
        let gibbs = Gibbs::new(vec![
            GibbsBlock::rwmh(&["var"], 0.3),
            GibbsBlock::hmc(&["m"], 0.05, 8),
        ]);
        let out = gibbs.sample(&m, &tvi, 1500, 6000, &mut rng);
        // column order: var, m
        let means: Vec<f64> = out.rows.iter().map(|r| r[1]).collect();
        assert!((stats::mean(&means) - 1.5).abs() < 0.1, "{}", stats::mean(&means));
        let vars: Vec<f64> = out.rows.iter().map(|r| r[0]).collect();
        assert!((stats::mean(&vars) - 0.49).abs() < 0.25, "{}", stats::mean(&vars));
    }

    #[test]
    fn gibbs_enumerates_discrete_exactly() {
        let m = TinyMixture { y: 2.0 };
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let tvi = init_typed(&m, &mut rng);
        let gibbs = Gibbs::new(vec![GibbsBlock::enumerate(&["z"])]);
        let out = gibbs.sample(&m, &tvi, 200, 4000, &mut rng);
        // posterior P(z=1|y=2) by Bayes
        let l1 = 0.3 * (-0.5f64).exp(); // N(2;3,1) ∝ exp(-0.5)
        let l0 = 0.7 * (-12.5f64).exp(); // N(2;-3,1) ∝ exp(-12.5)
        let expect = l1 / (l1 + l0);
        let freq: f64 =
            out.rows.iter().map(|r| r[0]).sum::<f64>() / out.rows.len() as f64;
        assert!((freq - expect).abs() < 0.03, "{freq} vs {expect}");
    }

    #[test]
    fn particle_gibbs_block_matches_exact_discrete_posterior() {
        // Same posterior check as the Enumerate test, but the discrete
        // latent is updated by conditional SMC instead of enumeration.
        let m = TinyMixture { y: 2.0 };
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let tvi = init_typed(&m, &mut rng);
        let gibbs = Gibbs::new(vec![GibbsBlock::particle_gibbs(&["z"], 24)]);
        let out = gibbs.sample(&m, &tvi, 200, 4000, &mut rng);
        let l1 = 0.3 * (-0.5f64).exp();
        let l0 = 0.7 * (-12.5f64).exp();
        let expect = l1 / (l1 + l0);
        let freq: f64 = out.rows.iter().map(|r| r[0]).sum::<f64>() / out.rows.len() as f64;
        assert!((freq - expect).abs() < 0.04, "{freq} vs {expect}");
    }

    #[test]
    fn particle_gibbs_mixed_with_hmc_recovers_continuous_posterior() {
        // PG over the variance block + HMC over the mean: posterior means
        // must agree with the all-HMC/MH baseline within a loose MCSE band.
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let y: Vec<f64> = (0..8).map(|_| 1.5 + 0.7 * rng.normal()).collect();
        let m = GaussUnknown { y };
        let tvi = init_typed(&m, &mut rng);

        let baseline = Gibbs::new(vec![
            GibbsBlock::rwmh(&["var"], 0.4),
            GibbsBlock::hmc(&["m"], 0.05, 8),
        ])
        .sample(&m, &tvi, 1000, 8000, &mut rng);

        let pg = Gibbs::new(vec![
            GibbsBlock::particle_gibbs(&["var"], 32),
            GibbsBlock::hmc(&["m"], 0.05, 8),
        ])
        .sample(&m, &tvi, 500, 4000, &mut rng);

        // column order: var, m
        let m_base = stats::mean(&baseline.rows.iter().map(|r| r[1]).collect::<Vec<_>>());
        let m_pg = stats::mean(&pg.rows.iter().map(|r| r[1]).collect::<Vec<_>>());
        assert!((m_base - m_pg).abs() < 0.15, "m: baseline {m_base} vs PG {m_pg}");
        let v_base = stats::mean(&baseline.rows.iter().map(|r| r[0]).collect::<Vec<_>>());
        let v_pg = stats::mean(&pg.rows.iter().map(|r| r[0]).collect::<Vec<_>>());
        assert!(
            (v_base - v_pg).abs() < 0.25 * (1.0 + v_base),
            "var: baseline {v_base} vs PG {v_pg}"
        );
    }

    #[test]
    fn masked_fused_gradient_matches_full_joint_on_block_coords() {
        use crate::model::{typed_grad_fused, typed_grad_fused_masked_into};
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        let y: Vec<f64> = (0..50).map(|_| 1.0 + 0.5 * rng.normal()).collect();
        let m = GaussUnknown { y };
        let tvi = init_typed(&m, &mut rng);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.7 + 0.1).collect();

        // block = {m}: the var site (and all glue hanging off it) is masked
        let mask: Vec<bool> = tvi.slots().iter().map(|s| s.vn == VarName::new("m")).collect();
        assert_eq!(mask.iter().filter(|&&b| b).count(), 1);
        let mut g_mask = vec![0.0; theta.len()];
        let lp_mask = typed_grad_fused_masked_into(
            &m,
            &tvi,
            &theta,
            Context::Default,
            &mask,
            &mut g_mask,
        );
        let nodes_masked = crate::ad::arena::last_stats().nodes;

        let (lp_full, g_full) = typed_grad_fused(&m, &tvi, &theta, Context::Default);
        let nodes_full = crate::ad::arena::last_stats().nodes;

        // the masked pass still scores the full joint — bitwise
        assert_eq!(lp_full.to_bits(), lp_mask.to_bits());
        // in-block gradient entries are bitwise identical; masked
        // coordinates come back exactly zero
        for (si, slot) in tvi.slots().iter().enumerate() {
            for c in slot.unc_offset..slot.unc_offset + slot.unc_len {
                if mask[si] {
                    assert_eq!(g_full[c].to_bits(), g_mask[c].to_bits(), "coord {c}");
                } else {
                    assert_eq!(g_mask[c], 0.0, "masked coord {c}");
                }
            }
        }
        // the whole point: out-of-block sites cost zero arena nodes
        // (var's invlink node and the (var*2).sqrt()/var.sqrt() glue gone)
        assert!(
            nodes_masked < nodes_full,
            "masked tape not smaller: {nodes_masked} vs {nodes_full}"
        );
        assert_eq!(nodes_masked, 0, "GaussUnknown's m-block tape should be all seeds");
    }

    #[test]
    fn gibbs_fused_grad_mixes_like_forward() {
        let mut rng = Xoshiro256pp::seed_from_u64(27);
        let y: Vec<f64> = (0..200).map(|_| 1.5 + 0.7 * rng.normal()).collect();
        let m = GaussUnknown { y };
        let tvi = init_typed(&m, &mut rng);
        let gibbs = Gibbs {
            blocks: vec![
                GibbsBlock::rwmh(&["var"], 0.3),
                GibbsBlock::hmc(&["m"], 0.05, 8),
            ],
            grad: GibbsGrad::Fused,
            // this test pins the fused-gradient path; keep the var block
            // on plain MH rather than letting the analyzer collapse it
            collapse: false,
        };
        let out = gibbs.sample(&m, &tvi, 1000, 4000, &mut rng);
        let means: Vec<f64> = out.rows.iter().map(|r| r[1]).collect();
        assert!((stats::mean(&means) - 1.5).abs() < 0.1, "{}", stats::mean(&means));
        assert!(out.logps.iter().all(|lp| lp.is_finite()));
    }

    #[test]
    #[should_panic(expected = "matches no variables")]
    fn unknown_block_var_panics() {
        let m = TinyMixture { y: 0.0 };
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let tvi = init_typed(&m, &mut rng);
        let gibbs = Gibbs::new(vec![GibbsBlock::rwmh(&["nope"], 0.1)]);
        let _ = gibbs.sample(&m, &tvi, 1, 1, &mut rng);
    }
}
