//! Multi-chain lane gang: K chains share one batched gradient pass.
//!
//! Each chain keeps its own sampler state — RNG stream, step size,
//! adaptation schedule, trajectory — and runs unmodified on its own
//! thread. The only shared piece is the gradient: a [`LaneDensity`]
//! handed to each chain routes `logp_grad_into` through a [`LaneGang`]
//! rendezvous, where the *last* chain to arrive packs every waiting
//! chain's θ into one lane-major buffer and runs a single
//! [`LogDensity::logp_grad_batch_into`] call (one K-lane tape walk on the
//! fused engine) while the rest block on a condvar.
//!
//! Because the batched engine is bit-identical per lane and every chain
//! consumes only its own RNG stream, the draws are bit-identical to
//! running the chains sequentially with the same seeds — batching changes
//! wall-clock, never results.
//!
//! Chains retire independently: NUTS trajectories take different numbers
//! of leapfrogs, and warmup lengths differ per config, so a chain that
//! finishes calls [`LaneGang::leave`] and the gang shrinks — later
//! rendezvous simply batch fewer lanes (down to plain sequential calls
//! when one chain remains). The rendezvous never times out: a missing
//! lane is always either about to submit or about to leave.

use std::sync::{Condvar, Mutex};

use crate::gradient::LogDensity;

struct GangState {
    /// Lanes still sampling (submitters the rendezvous waits for).
    active: usize,
    /// Lanes currently parked in this round.
    submitted: usize,
    /// Round counter: bumped once per batched evaluation so parked lanes
    /// know their results are ready.
    generation: u64,
    /// Which lane slots hold a pending θ this round.
    present: Vec<bool>,
    /// Per-lane slots, lane-major (`[lane * dim ..]`); each slot is
    /// written only by its own lane, so slots survive across rounds
    /// without handshakes.
    thetas: Vec<f64>,
    lps: Vec<f64>,
    grads: Vec<f64>,
    /// Contiguous pack buffers for the batched call (submitted lanes
    /// only, in ascending lane order).
    pack_thetas: Vec<f64>,
    pack_lps: Vec<f64>,
    pack_grads: Vec<f64>,
}

/// Rendezvous point for K lane threads sharing one [`LogDensity`].
pub struct LaneGang<'a> {
    ld: &'a dyn LogDensity,
    dim: usize,
    state: Mutex<GangState>,
    cv: Condvar,
}

impl<'a> LaneGang<'a> {
    pub fn new(ld: &'a dyn LogDensity, lanes: usize) -> Self {
        assert!(lanes > 0);
        let dim = ld.dim();
        Self {
            ld,
            dim,
            state: Mutex::new(GangState {
                active: lanes,
                submitted: 0,
                generation: 0,
                present: vec![false; lanes],
                thetas: vec![0.0; lanes * dim],
                lps: vec![0.0; lanes],
                grads: vec![0.0; lanes * dim],
                pack_thetas: vec![0.0; lanes * dim],
                pack_lps: vec![0.0; lanes],
                pack_grads: vec![0.0; lanes * dim],
            }),
            cv: Condvar::new(),
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Plain log-density needs no gang: it is cheap relative to gradients
    /// and appears off the leapfrog hot loop (initialization, divergence
    /// checks), where waiting on a rendezvous would deadlock against
    /// lanes that never make the matching call.
    pub fn logp(&self, theta: &[f64]) -> f64 {
        self.ld.logp(theta)
    }

    /// Submit this lane's θ and block until the round's batched gradient
    /// evaluation has run (the last arriver runs it in-lock).
    pub fn logp_grad_into(&self, lane: usize, theta: &[f64], grad: &mut [f64]) -> f64 {
        let dim = self.dim;
        let mut st = self.state.lock().expect("lane gang poisoned");
        debug_assert!(!st.present[lane], "lane {lane} double-submitted");
        st.thetas[lane * dim..(lane + 1) * dim].copy_from_slice(theta);
        st.present[lane] = true;
        st.submitted += 1;
        let gen = st.generation;
        if st.submitted == st.active {
            self.run_round(&mut st);
            self.cv.notify_all();
        } else {
            while st.generation == gen {
                st = self.cv.wait(st).expect("lane gang poisoned");
            }
        }
        grad.copy_from_slice(&st.grads[lane * dim..(lane + 1) * dim]);
        st.lps[lane]
    }

    /// This lane is done sampling; if everyone else is already parked,
    /// run their round on the way out.
    pub fn leave(&self, lane: usize) {
        let mut st = self.state.lock().expect("lane gang poisoned");
        debug_assert!(!st.present[lane], "lane {lane} left mid-round");
        st.active -= 1;
        if st.active > 0 && st.submitted == st.active {
            self.run_round(&mut st);
            self.cv.notify_all();
        }
    }

    /// Pack the submitted lanes contiguously, run one batched gradient
    /// call, scatter results back to the per-lane slots.
    fn run_round(&self, st: &mut GangState) {
        let dim = self.dim;
        let k = st.submitted;
        debug_assert!(k > 0);
        let members: Vec<usize> = (0..st.present.len()).filter(|&l| st.present[l]).collect();
        debug_assert_eq!(members.len(), k);
        for (i, &l) in members.iter().enumerate() {
            st.pack_thetas[i * dim..(i + 1) * dim]
                .copy_from_slice(&st.thetas[l * dim..(l + 1) * dim]);
        }
        self.ld.logp_grad_batch_into(
            &st.pack_thetas[..k * dim],
            &mut st.pack_lps[..k],
            &mut st.pack_grads[..k * dim],
        );
        for (i, &l) in members.iter().enumerate() {
            st.lps[l] = st.pack_lps[i];
            st.grads[l * dim..(l + 1) * dim]
                .copy_from_slice(&st.pack_grads[i * dim..(i + 1) * dim]);
            st.present[l] = false;
        }
        st.submitted = 0;
        st.generation += 1;
    }
}

/// One lane's view of the gang — a [`LogDensity`] a stock sampler can
/// drive without knowing it shares gradient passes with K−1 siblings.
pub struct LaneDensity<'g, 'a> {
    gang: &'g LaneGang<'a>,
    lane: usize,
}

impl<'g, 'a> LaneDensity<'g, 'a> {
    pub fn new(gang: &'g LaneGang<'a>, lane: usize) -> Self {
        Self { gang, lane }
    }
}

impl<'g, 'a> LogDensity for LaneDensity<'g, 'a> {
    fn dim(&self) -> usize {
        self.gang.dim()
    }

    fn logp(&self, theta: &[f64]) -> f64 {
        self.gang.logp(theta)
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let mut g = vec![0.0; self.gang.dim()];
        let lp = self.gang.logp_grad_into(self.lane, theta, &mut g);
        (lp, g)
    }

    fn logp_grad_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        self.gang.logp_grad_into(self.lane, theta, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::std_normal_density;

    #[test]
    fn gang_matches_direct_evaluation_across_threads() {
        let ld = std_normal_density(3);
        let gang = LaneGang::new(&ld, 4);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|l| {
                    let gang = &gang;
                    s.spawn(move || {
                        let lane = LaneDensity::new(gang, l);
                        let base = l as f64;
                        let mut g = vec![0.0; 3];
                        // different call counts per lane: lane l does l+1
                        // rounds before leaving — the gang must shrink
                        for r in 0..=l {
                            let th = [base + r as f64, -base, 0.5 * base];
                            let lp = lane.logp_grad_into(&th, &mut g);
                            let (elp, eg) = ld.logp_grad(&th);
                            assert_eq!(lp.to_bits(), elp.to_bits());
                            assert_eq!(g, eg);
                        }
                        gang.leave(l);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }
}
