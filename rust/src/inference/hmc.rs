//! Static Hamiltonian Monte Carlo — the paper's benchmark sampler
//! ("static HMC with 4 leapfrog steps for 2,000 iterations").

use rand_core::RngCore;

use crate::chain::SamplerStats;
use crate::gradient::LogDensity;
use crate::obs::metrics::{self, Counter};
use crate::util::rng::Rng;

use super::adapt::{DualAveraging, WelfordVar};
use super::RawDraws;

/// Static HMC configuration.
#[derive(Clone, Debug)]
pub struct Hmc {
    /// Leapfrog step size ε (initial value if `adapt_step_size`).
    pub step_size: f64,
    /// Number of leapfrog steps per proposal (paper: 4).
    pub n_leapfrog: usize,
    /// Adapt ε by dual averaging during warmup.
    pub adapt_step_size: bool,
    /// Adapt a diagonal mass matrix during warmup.
    pub adapt_mass: bool,
    /// Dual-averaging target acceptance.
    pub target_accept: f64,
    /// Probe a starting ε with the warmup adapter's doubling heuristic
    /// ([`super::adapt::find_initial_step_size`]) before dual averaging
    /// takes over, instead of trusting `step_size` blindly. Default-on
    /// since the seeded statistical tests were re-baselined with the
    /// probe enabled ([`Hmc::paper`] keeps it off: the paper config is a
    /// fixed-ε benchmark).
    pub init_step_size: bool,
}

impl Default for Hmc {
    fn default() -> Self {
        Self {
            step_size: 0.1,
            n_leapfrog: 4,
            adapt_step_size: true,
            adapt_mass: false,
            target_accept: 0.8,
            init_step_size: true,
        }
    }
}

impl Hmc {
    /// Paper configuration: fixed ε, 4 leapfrog steps, no adaptation.
    pub fn paper(step_size: f64) -> Self {
        Self {
            step_size,
            n_leapfrog: 4,
            adapt_step_size: false,
            adapt_mass: false,
            target_accept: 0.8,
            init_step_size: false,
        }
    }

    /// Draw `iters` post-warmup samples starting at `theta0` (unconstrained).
    ///
    /// Total model evaluations: `(warmup + iters) × (n_leapfrog + 1)` grad
    /// calls — the quantity the Table-1 benchmarks time.
    pub fn sample<R: RngCore>(
        &self,
        ld: &dyn LogDensity,
        theta0: &[f64],
        warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> RawDraws {
        let dim = ld.dim();
        assert_eq!(theta0.len(), dim);
        let t_start = std::time::Instant::now();

        let mut theta = theta0.to_vec();
        let mut grad = vec![0.0; dim];
        let mut lp = ld.logp_grad_into(&theta, &mut grad);
        assert!(
            lp.is_finite(),
            "HMC initialized at a zero-probability point (logp = {lp})"
        );
        let mut n_grad: u64 = 1;

        let mut eps = self.step_size;
        if self.init_step_size {
            let (probed, evals) =
                super::adapt::find_initial_step_size(ld, &theta, self.step_size, rng);
            eps = probed;
            n_grad += evals;
        }
        let mut da = DualAveraging::new(eps, self.target_accept);
        let mut mass_est = WelfordVar::new(dim);
        // inv_mass[i] = estimated posterior variance of coordinate i
        let mut inv_mass: Vec<f64> = vec![1.0; dim];

        let mut thetas = Vec::with_capacity(iters);
        let mut logps = Vec::with_capacity(iters);
        let mut accepts = 0.0f64;
        let mut divergences = 0usize;
        let mut n_leap: u64 = 0;
        let mut warmup_secs = 0.0;
        // per-iteration Hamiltonians (E-BFMI input); recorded only while
        // telemetry is live so the disabled path allocates nothing
        let mut energies: Vec<f64> = Vec::new();

        // scratch buffers reused across iterations (no allocation in the
        // hot loop — see EXPERIMENTS.md §Perf)
        let mut p = vec![0.0; dim];
        let mut theta_prop = vec![0.0; dim];
        let mut grad_prop = vec![0.0; dim];

        for it in 0..warmup + iters {
            // momentum ~ N(0, M) with M = diag(1/inv_mass)
            for i in 0..dim {
                p[i] = rng.normal() / inv_mass[i].sqrt();
            }
            // kinetic energy: ½ pᵀ M⁻¹ p
            let ke0: f64 = 0.5
                * p.iter()
                    .zip(&inv_mass)
                    .map(|(&pi, &im)| pi * pi * im)
                    .sum::<f64>();
            let h0 = -lp + ke0;

            theta_prop.copy_from_slice(&theta);
            grad_prop.copy_from_slice(&grad);
            let mut lp_prop = lp;
            let mut diverged = false;

            // leapfrog trajectory — gradients land in the reused buffer
            // (`logp_grad_into`): with the fused backend the sampler and
            // gradient engine allocate nothing here (the one exception is
            // the `Vec` each vector-valued assume must hand the model
            // body, inherent to the `TildeApi` contract)
            for _ in 0..self.n_leapfrog {
                for i in 0..dim {
                    p[i] += 0.5 * eps * grad_prop[i];
                    theta_prop[i] += eps * p[i] * inv_mass[i];
                }
                let l = ld.logp_grad_into(&theta_prop, &mut grad_prop);
                n_grad += 1;
                n_leap += 1;
                lp_prop = l;
                if !l.is_finite() {
                    diverged = true;
                    break;
                }
                for i in 0..dim {
                    p[i] += 0.5 * eps * grad_prop[i];
                }
            }

            let accept_prob = if diverged {
                0.0
            } else {
                let ke1: f64 = 0.5
                    * p.iter()
                        .zip(&inv_mass)
                        .map(|(&pi, &im)| pi * pi * im)
                        .sum::<f64>();
                let h1 = -lp_prop + ke1;
                if (h1 - h0) > 1000.0 {
                    divergences += 1;
                }
                ((h0 - h1).exp()).min(1.0)
            };
            if diverged {
                divergences += 1;
            }

            if rng.uniform() < accept_prob {
                std::mem::swap(&mut theta, &mut theta_prop);
                std::mem::swap(&mut grad, &mut grad_prop);
                lp = lp_prop;
            }

            if it < warmup {
                if self.adapt_step_size {
                    eps = da.update(accept_prob);
                }
                if self.adapt_mass {
                    mass_est.push(&theta);
                    if mass_est.count() > 50 {
                        inv_mass = mass_est.variance();
                    }
                }
                if it + 1 == warmup {
                    if self.adapt_step_size {
                        eps = da.finalized();
                    }
                    warmup_secs = t_start.elapsed().as_secs_f64();
                }
            } else {
                accepts += accept_prob;
                if metrics::enabled() {
                    energies.push(h0);
                }
                thetas.push(theta.clone());
                logps.push(lp);
            }
        }

        metrics::add(Counter::LeapfrogSteps, n_leap);
        metrics::add(Counter::Divergences, divergences as u64);
        let wall_secs = t_start.elapsed().as_secs_f64();
        RawDraws {
            thetas,
            logps,
            stats: SamplerStats {
                accept_rate: if iters > 0 { accepts / iters as f64 } else { 0.0 },
                divergences,
                step_size: eps,
                n_grad_evals: n_grad,
                wall_secs,
                warmup_secs,
                sampling_secs: wall_secs - warmup_secs,
                energies,
                ..SamplerStats::default()
            },
        }
    }
}

/// Static HMC over the fused XLA trajectory artifact (§Perf): identical
/// proposal distribution to [`Hmc::paper`] with identity mass, but each
/// iteration is **one** PJRT call instead of `n_leapfrog + 1`.
pub struct HmcFusedXla<'a> {
    pub traj: &'a crate::runtime::XlaTrajectory,
    /// plain value_and_grad artifact, used once for the initial log-density
    pub vg: &'a crate::runtime::XlaDensity,
    pub step_size: f64,
}

impl<'a> HmcFusedXla<'a> {
    pub fn sample<R: RngCore>(
        &self,
        theta0: &[f64],
        warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> RawDraws {
        let dim = self.traj.dim();
        let t_start = std::time::Instant::now();
        let mut theta = theta0.to_vec();
        let (mut lp, mut grad) = self.vg.logp_grad(&theta);
        assert!(lp.is_finite(), "fused HMC initialized at logp = {lp}");

        let mut thetas = Vec::with_capacity(iters);
        let mut logps = Vec::with_capacity(iters);
        let mut accepts = 0.0;
        let mut divergences = 0usize;
        let mut p = vec![0.0; dim];
        let mut theta_prop = vec![0.0; dim];
        let mut grad_prop = vec![0.0; dim];
        let mut n_traj = 0u64;
        let mut warmup_secs = 0.0;
        let mut energies: Vec<f64> = Vec::new();

        for it in 0..warmup + iters {
            for pi in p.iter_mut() {
                *pi = rng.normal();
            }
            let ke0: f64 = 0.5 * p.iter().map(|x| x * x).sum::<f64>();
            let h0 = -lp + ke0;
            theta_prop.copy_from_slice(&theta);
            grad_prop.copy_from_slice(&grad);
            // one PJRT call runs the whole trajectory; the gradient is
            // threaded through so each iteration costs exactly n_leapfrog
            // gradient evaluations, like the unfused sampler
            let lp_prop = self
                .traj
                .run(&mut theta_prop, &mut p, self.step_size, &mut grad_prop)
                .expect("trajectory execution failed");
            n_traj += 1;
            let accept_prob = if lp_prop.is_finite() {
                let ke1: f64 = 0.5 * p.iter().map(|x| x * x).sum::<f64>();
                ((h0 - (-lp_prop + ke1)).exp()).min(1.0)
            } else {
                divergences += 1;
                0.0
            };
            if rng.uniform() < accept_prob {
                std::mem::swap(&mut theta, &mut theta_prop);
                std::mem::swap(&mut grad, &mut grad_prop);
                lp = lp_prop;
            }
            if it >= warmup {
                accepts += accept_prob;
                if metrics::enabled() {
                    energies.push(h0);
                }
                thetas.push(theta.clone());
                logps.push(lp);
            }
            if it + 1 == warmup {
                warmup_secs = t_start.elapsed().as_secs_f64();
            }
        }

        metrics::add(Counter::LeapfrogSteps, n_traj * 4);
        metrics::add(Counter::Divergences, divergences as u64);
        let wall_secs = t_start.elapsed().as_secs_f64();
        RawDraws {
            thetas,
            logps,
            stats: SamplerStats {
                accept_rate: if iters > 0 { accepts / iters as f64 } else { 0.0 },
                divergences,
                step_size: self.step_size,
                n_grad_evals: n_traj * 4,
                wall_secs,
                warmup_secs,
                sampling_secs: wall_secs - warmup_secs,
                energies,
                ..SamplerStats::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::{std_normal_density, FnDensity};
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    #[test]
    fn std_normal_moments() {
        let ld = std_normal_density(3);
        let mut rng = Xoshiro256pp::seed_from_u64(42);
        let hmc = Hmc::default();
        let out = hmc.sample(&ld, &[0.5, -0.5, 0.0], 500, 4000, &mut rng);
        assert_eq!(out.thetas.len(), 4000);
        for i in 0..3 {
            let col: Vec<f64> = out.thetas.iter().map(|t| t[i]).collect();
            assert!(stats::mean(&col).abs() < 0.1, "dim {i}");
            assert!((stats::variance(&col) - 1.0).abs() < 0.15, "dim {i}");
        }
        assert!(out.stats.accept_rate > 0.6);
    }

    #[test]
    fn correlated_target_with_mass_adaptation() {
        // N(0, diag(100, 0.01)): needs mass adaptation to mix both dims
        let ld = FnDensity {
            dim: 2,
            f: |t: &[f64]| -0.5 * (t[0] * t[0] / 100.0 + t[1] * t[1] / 0.01),
            g: |t: &[f64]| {
                (
                    -0.5 * (t[0] * t[0] / 100.0 + t[1] * t[1] / 0.01),
                    vec![-t[0] / 100.0, -t[1] / 0.01],
                )
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let hmc = Hmc {
            n_leapfrog: 16,
            adapt_mass: true,
            ..Hmc::default()
        };
        let out = hmc.sample(&ld, &[1.0, 0.01], 1500, 6000, &mut rng);
        let c0: Vec<f64> = out.thetas.iter().map(|t| t[0]).collect();
        let c1: Vec<f64> = out.thetas.iter().map(|t| t[1]).collect();
        assert!((stats::variance(&c0) - 100.0).abs() < 30.0, "{}", stats::variance(&c0));
        assert!((stats::variance(&c1) - 0.01).abs() < 0.004, "{}", stats::variance(&c1));
    }

    #[test]
    fn paper_config_runs_fixed_eps() {
        let ld = std_normal_density(2);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let hmc = Hmc::paper(0.3);
        let out = hmc.sample(&ld, &[0.0, 0.0], 0, 500, &mut rng);
        assert_eq!(out.stats.step_size, 0.3);
        assert_eq!(out.thetas.len(), 500);
        // grad evals: ≤ (0 + 500) × 4 + 1 initial (divergent trajectories
        // break the leapfrog loop early)
        assert!(out.stats.n_grad_evals <= 500 * 4 + 1);
        assert!(out.stats.n_grad_evals > 500 * 2);
    }

    #[test]
    #[should_panic(expected = "zero-probability")]
    fn rejects_invalid_init() {
        let ld = FnDensity {
            dim: 1,
            f: |_: &[f64]| f64::NEG_INFINITY,
            g: |_: &[f64]| (f64::NEG_INFINITY, vec![0.0]),
        };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        Hmc::default().sample(&ld, &[0.0], 10, 10, &mut rng);
    }
}
