//! Inference algorithms: static HMC (the paper's benchmark sampler), NUTS,
//! random-walk Metropolis–Hastings, blocked Gibbs, sequential Monte Carlo
//! (SMC + Particle-Gibbs over the `particle` substrate), and prior
//! sampling — the Turing/AdvancedHMC/AdvancedPS layer of the paper's
//! stack. Variational inference lives in [`crate::vi`] and plugs in here
//! through [`SamplerKind::Advi`].

pub mod adapt;
pub mod gibbs;
pub mod hmc;
pub mod lanes;
pub mod mh;
pub mod nuts;
pub mod run;
pub mod smc;

pub use gibbs::{BlockSampler, Gibbs, GibbsBlock};
pub use hmc::Hmc;
pub use mh::RwMh;
pub use nuts::Nuts;
pub use lanes::{LaneDensity, LaneGang};
pub use run::{
    raw_to_chain, sample_chain, sample_chains, sample_chains_batched, sample_smc_chain,
    SamplerKind,
};
pub use smc::{csmc_sweep, Csmc, Smc, SmcCloud, SmcResult};

use crate::chain::SamplerStats;

/// Raw sampler output: unconstrained draws + per-draw log-density.
#[derive(Clone, Debug)]
pub struct RawDraws {
    pub thetas: Vec<Vec<f64>>,
    pub logps: Vec<f64>,
    pub stats: SamplerStats,
}
