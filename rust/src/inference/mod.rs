//! Inference algorithms: static HMC (the paper's benchmark sampler), NUTS,
//! random-walk Metropolis–Hastings, blocked Gibbs, and prior sampling —
//! the Turing/AdvancedHMC layer of the paper's stack.

pub mod adapt;
pub mod gibbs;
pub mod hmc;
pub mod mh;
pub mod nuts;
pub mod run;

pub use gibbs::{Gibbs, GibbsBlock};
pub use hmc::Hmc;
pub use mh::RwMh;
pub use nuts::Nuts;
pub use run::{sample_chain, sample_chains, SamplerKind};

use crate::chain::SamplerStats;

/// Raw sampler output: unconstrained draws + per-draw log-density.
#[derive(Clone, Debug)]
pub struct RawDraws {
    pub thetas: Vec<Vec<f64>>,
    pub logps: Vec<f64>,
    pub stats: SamplerStats,
}
