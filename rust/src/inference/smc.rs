//! Sequential Monte Carlo over the trace machinery (the inference family
//! the paper's `VarInfo` design exists to power in Turing.jl), plus the
//! conditional-SMC sweep used by Particle-Gibbs.
//!
//! [`Smc`] runs a bootstrap particle filter over a model's observe
//! statements: particles are whole execution traces, propagation is
//! replay-with-regenerate re-execution ([`crate::particle`]), resampling
//! is ESS-triggered, and the running normalizers accumulate an unbiased
//! log-marginal-likelihood (evidence) estimate — a quantity none of the
//! gradient samplers can produce.
//!
//! Parallelism: particle propagation fans out over
//! [`crate::util::threadpool::parallel_for_each_mut`]. Results are
//! **bitwise deterministic** in the seed regardless of thread count
//! because per-particle RNG streams are indexed by `(seed, step,
//! particle)` and every reduction (weights, evidence, resampling) runs
//! serially on the caller thread.

use std::time::Instant;

use std::collections::HashMap;

use crate::chain::{Chain, SamplerStats};
use crate::context::Context;
use crate::model::{sample_run, Model};
use crate::particle::{particle_seed, ParticleCloud, Resampler};
use crate::util::rng::Xoshiro256pp;
use crate::varinfo::{TypedVarInfo, UntypedVarInfo};
use crate::varname::VarName;

/// Sequential Monte Carlo (bootstrap particle filter) configuration.
#[derive(Clone, Debug)]
pub struct Smc {
    /// Number of particles (≥ 2; hundreds+ for evidence estimates).
    pub n_particles: usize,
    /// Resampling scheme (systematic has the lowest variance).
    pub resampler: Resampler,
    /// Resample when `ESS < ess_threshold · N`; 1.0 = every step.
    pub ess_threshold: f64,
    /// Worker threads for particle propagation (1 = serial; any value
    /// yields identical results for a fixed seed).
    pub threads: usize,
}

impl Default for Smc {
    fn default() -> Self {
        Self {
            n_particles: 256,
            resampler: Resampler::Systematic,
            ess_threshold: 0.5,
            threads: 1,
        }
    }
}

/// Outcome of one SMC run.
pub struct SmcResult {
    /// Final weighted cloud (post last observation; not equalized).
    pub cloud: ParticleCloud,
    /// Log-marginal-likelihood estimate `log Ẑ`.
    pub log_evidence: f64,
    /// ESS after each observation step.
    pub ess_trace: Vec<f64>,
    /// Number of resampling passes triggered.
    pub resamples: usize,
    pub wall_secs: f64,
}

impl Smc {
    pub fn new(n_particles: usize) -> Self {
        Self {
            n_particles,
            ..Smc::default()
        }
    }

    /// Run the filter over every observe statement of `model`.
    pub fn run(&self, model: &dyn Model, seed: u64) -> SmcResult {
        assert!(self.n_particles >= 2);
        assert!(self.ess_threshold > 0.0 && self.ess_threshold <= 1.0);
        let t0 = Instant::now();
        let mut cloud = ParticleCloud::from_prior(model, self.n_particles, seed, self.threads);
        // master stream: resampling decisions only (serial → deterministic)
        let mut master =
            Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0x5EED));
        let mut ess_trace = Vec::with_capacity(cloud.n_obs);
        let mut resamples = 0usize;
        for t in 0..cloud.n_obs {
            cloud.advance(model, seed, self.threads);
            ess_trace.push(cloud.ess());
            // keep the final cloud weighted: no resample after the last step
            if t + 1 < cloud.n_obs
                && cloud.maybe_resample(self.resampler, self.ess_threshold, false, &mut master)
            {
                resamples += 1;
            }
        }
        SmcResult {
            log_evidence: cloud.log_evidence,
            cloud,
            ess_trace,
            resamples,
            wall_secs: t0.elapsed().as_secs_f64(),
        }
    }

    /// Run the filter and return an equal-weight [`Chain`]: the final
    /// cloud is resampled to uniform weights and each particle becomes
    /// one constrained-space draw (`len == n_particles`). The chain's
    /// `stats.log_evidence` carries the evidence estimate.
    pub fn sample_chain(&self, model: &dyn Model, seed: u64) -> Chain {
        let result = self.run(model, seed);
        let t0 = Instant::now();
        let mut master =
            Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0xCA1A));
        let weights = result.cloud.weights();
        let ancestors = self
            .resampler
            .ancestors(&weights, self.n_particles, &mut master);

        // resampling duplicates ancestors heavily on peaked posteriors:
        // replay/convert each unique ancestor once, push its row k times
        let mut rows: HashMap<usize, (Vec<f64>, f64)> = HashMap::new();
        let mut chain: Option<Chain> = None;
        for &a in &ancestors {
            if !rows.contains_key(&a) {
                let mut trace = result.cloud.particles[a].trace.clone();
                // full-joint replay (values all present → pure replay)
                let lp = sample_run(model, &mut master, &mut trace, Context::Default);
                let tvi = TypedVarInfo::from_untyped(&trace);
                if chain.is_none() {
                    chain = Some(Chain::new(tvi.column_names()));
                }
                rows.insert(a, (tvi.row(), lp));
            }
            let (row, lp) = &rows[&a];
            chain
                .as_mut()
                .expect("chain initialized with first ancestor")
                .push(row.clone(), *lp);
        }
        let mut chain = chain.expect("SMC produced an empty cloud");
        chain.stats = SamplerStats {
            accept_rate: 1.0,
            wall_secs: result.wall_secs + t0.elapsed().as_secs_f64(),
            log_evidence: result.log_evidence,
            ..SamplerStats::default()
        };
        chain
    }
}

/// One conditional-SMC (Particle-Gibbs) sweep: run an N-particle filter
/// in which particle 0 is pinned to the `reference` trajectory's values
/// of the `scope` variables (all other variables replay exactly in every
/// particle), then draw one particle from the final weights. The returned
/// trace is a sample from a Markov kernel that leaves the conditional
/// posterior of `scope` invariant (Andrieu, Doucet & Holenstein 2010).
///
/// Multinomial resampling is the safe scheme for the conditional filter
/// and the Particle-Gibbs default.
///
/// `n_obs` is the model's observe-statement count: pass
/// `Some(crate::particle::count_observes(model, reference))` computed
/// once when sweeping in a loop (Gibbs does), or `None` to probe here.
pub fn csmc_sweep(
    model: &dyn Model,
    reference: &UntypedVarInfo,
    scope: &[VarName],
    n_particles: usize,
    resampler: Resampler,
    ess_threshold: f64,
    seed: u64,
    n_obs: Option<usize>,
) -> UntypedVarInfo {
    let mut cloud =
        ParticleCloud::conditional(model, reference, scope, n_particles, seed, n_obs);
    let mut master = Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0xC5bC));
    for t in 0..cloud.n_obs {
        cloud.advance(model, seed, 1);
        if t + 1 < cloud.n_obs {
            cloud.maybe_resample(resampler, ess_threshold, true, &mut master);
        }
    }
    let k = cloud.select(&mut master);
    cloud.particles.swap_remove(k).trace
}

#[cfg(test)]
mod tests {
    use rand_core::RngCore;

    use super::*;
    use crate::prelude::*;
    use crate::util::stats;

    model! {
        /// Conjugate Normal–Normal: m ~ N(mu0, tau0); y_t ~ N(m, sigma).
        pub NormalNormal {
            y: Vec<f64>,
            mu0: f64,
            tau0: f64,
            sigma: f64,
        }
        fn body<T>(this, api) {
            let m = tilde!(api, m ~ Normal(c(this.mu0), c(this.tau0)));
            for &yi in &this.y {
                obs!(api, yi => Normal(m, c(this.sigma)));
            }
        }
    }

    /// Closed-form log-evidence by sequential 1-D conjugate updates:
    /// log p(y) = Σ_t log N(y_t; μ_{t−1}, √(σ² + τ²_{t−1})).
    pub fn analytic_log_evidence(y: &[f64], mu0: f64, tau0: f64, sigma: f64) -> f64 {
        let (mut mu, mut tau2) = (mu0, tau0 * tau0);
        let s2 = sigma * sigma;
        let mut lz = 0.0;
        for &yt in y {
            let pred_var = s2 + tau2;
            lz += Normal::new(mu, pred_var.sqrt()).logpdf(yt);
            // posterior update
            let k = tau2 / pred_var;
            mu += k * (yt - mu);
            tau2 *= 1.0 - k;
        }
        lz
    }

    fn demo_data() -> Vec<f64> {
        // mild data near the prior mean: low weight variance
        vec![0.4, -0.1, 0.7, 0.2, -0.3, 0.5]
    }

    #[test]
    fn smc_recovers_analytic_evidence_within_two_percent() {
        let y = demo_data();
        let m = NormalNormal {
            y: y.clone(),
            mu0: 0.0,
            tau0: 1.0,
            sigma: 1.0,
        };
        let want = analytic_log_evidence(&y, 0.0, 1.0, 1.0);
        let smc = Smc {
            n_particles: 4096,
            ..Smc::default()
        };
        let out = smc.run(&m, 42);
        assert_eq!(out.ess_trace.len(), y.len());
        assert!(
            ((out.log_evidence - want) / want).abs() < 0.02,
            "SMC log-evidence {} vs analytic {want}",
            out.log_evidence
        );
    }

    #[test]
    fn smc_posterior_matches_conjugate_posterior() {
        let y = demo_data();
        let m = NormalNormal {
            y: y.clone(),
            mu0: 0.0,
            tau0: 1.0,
            sigma: 1.0,
        };
        // conjugate posterior of m
        let n = y.len() as f64;
        let post_var = 1.0 / (1.0 + n);
        let post_mean = post_var * y.iter().sum::<f64>();
        let chain = Smc {
            n_particles: 2048,
            ..Smc::default()
        }
        .sample_chain(&m, 7);
        assert_eq!(chain.len(), 2048);
        let ms = chain.column("m").unwrap();
        assert!(
            (stats::mean(&ms) - post_mean).abs() < 0.05,
            "{} vs {post_mean}",
            stats::mean(&ms)
        );
        assert!(
            (stats::variance(&ms) - post_var).abs() < 0.05,
            "{} vs {post_var}",
            stats::variance(&ms)
        );
        assert!(chain.stats.log_evidence.is_finite());
    }

    #[test]
    fn parallel_propagation_is_bitwise_deterministic() {
        let m = NormalNormal {
            y: demo_data(),
            mu0: 0.0,
            tau0: 1.0,
            sigma: 1.0,
        };
        let run = |threads: usize| {
            let smc = Smc {
                n_particles: 512,
                threads,
                ..Smc::default()
            };
            smc.run(&m, 1234)
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(
            serial.log_evidence.to_bits(),
            parallel.log_evidence.to_bits(),
            "evidence must be bitwise identical across thread counts"
        );
        for (a, b) in serial
            .cloud
            .particles
            .iter()
            .zip(&parallel.cloud.particles)
        {
            assert_eq!(a.log_weight.to_bits(), b.log_weight.to_bits());
            let ma = a.trace.get(&VarName::new("m")).unwrap().value.clone();
            let mb = b.trace.get(&VarName::new("m")).unwrap().value.clone();
            assert_eq!(ma, mb);
        }
        // and fully reproducible for the same seed
        let again = run(4);
        assert_eq!(parallel.log_evidence.to_bits(), again.log_evidence.to_bits());
    }

    #[test]
    fn csmc_sweep_is_a_valid_conditional_kernel() {
        // Iterated CSMC on the conjugate model must traverse the
        // posterior of m: run a short PG chain by hand and check moments.
        let y = demo_data();
        let m = NormalNormal {
            y: y.clone(),
            mu0: 0.0,
            tau0: 1.0,
            sigma: 1.0,
        };
        let n = y.len() as f64;
        let post_var = 1.0 / (1.0 + n);
        let post_mean = post_var * y.iter().sum::<f64>();

        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut state = crate::model::init_trace(&m, &mut rng);
        let scope = [VarName::new("m")];
        let n_obs = Some(crate::particle::count_observes(&m, &state));
        let mut draws = Vec::new();
        for it in 0..3000 {
            state = csmc_sweep(
                &m,
                &state,
                &scope,
                16,
                Resampler::Multinomial,
                0.5,
                rng.next_u64(),
                n_obs,
            );
            if it >= 200 {
                draws.push(state.get(&VarName::new("m")).unwrap().value.as_f64().unwrap());
            }
        }
        assert!(
            (stats::mean(&draws) - post_mean).abs() < 0.06,
            "PG mean {} vs {post_mean}",
            stats::mean(&draws)
        );
        assert!(
            (stats::variance(&draws) - post_var).abs() < 0.06,
            "PG var {} vs {post_var}",
            stats::variance(&draws)
        );
    }
}
