//! Sequential Monte Carlo over the trace machinery (the inference family
//! the paper's `VarInfo` design exists to power in Turing.jl), plus the
//! conditional-SMC sweep used by Particle-Gibbs.
//!
//! [`Smc`] runs a bootstrap particle filter over a model's observe
//! statements: particles are whole execution traces, propagation is
//! replay-with-regenerate re-execution ([`crate::particle`]), resampling
//! is ESS-triggered, and the running normalizers accumulate an unbiased
//! log-marginal-likelihood (evidence) estimate — a quantity none of the
//! gradient samplers can produce.
//!
//! **Typed specialization.** The first full run (prior initialization)
//! executes on boxed traces — the only representation that can discover a
//! model's structure. When every particle comes back with the same
//! layout, the cloud is *promoted* onto forked [`TypedVarInfo`] buffers
//! and the whole sweep runs as flat cursor walks (paper §2.2 applied to
//! particles). A dynamic structure change mid-sweep rolls the step back
//! and transparently *demotes* to the boxed path — same seeds, same
//! stream discipline, so a demoted run is bitwise identical to a run that
//! had been boxed from the start. [`SmcResult::typed_steps`] /
//! [`SmcResult::demotions`] report which path actually executed.
//!
//! Parallelism: particle propagation fans out over
//! [`crate::util::threadpool::parallel_for_each_mut`]. Results are
//! **bitwise deterministic** in the seed regardless of thread count
//! because per-particle RNG streams are indexed by `(seed, step,
//! particle)` and every reduction (weights, evidence, resampling) runs
//! serially on the caller thread.

use std::time::Instant;

use std::collections::HashMap;

use crate::chain::{Chain, SamplerStats};
use crate::context::Context;
use crate::obs::metrics::{self, Counter};
use crate::model::executors::{ReplayScope, TypedReplayExecutor};
use crate::model::{sample_run, Model};
use crate::particle::{
    count_observes, particle_seed, BoxedCloud, LayoutMismatch, ParticleCloud, ParticleState,
    Resampler, TypedCloud,
};
use crate::util::rng::Xoshiro256pp;
use crate::value::Value;
use crate::varinfo::{TypedVarInfo, UntypedVarInfo};
use crate::varname::VarName;

/// Sequential Monte Carlo (bootstrap particle filter) configuration.
#[derive(Clone, Debug)]
pub struct Smc {
    /// Number of particles (≥ 2; hundreds+ for evidence estimates).
    pub n_particles: usize,
    /// Resampling scheme (systematic has the lowest variance).
    pub resampler: Resampler,
    /// Resample when `ESS < ess_threshold · N`; 1.0 = every step.
    pub ess_threshold: f64,
    /// Worker threads for particle propagation (1 = serial; any value
    /// yields identical results for a fixed seed).
    pub threads: usize,
    /// Promote to the typed fast path after the first full run when the
    /// layout holds (default). `false` forces the boxed `ReplayExecutor`
    /// path — the benchmark baseline and a debugging escape hatch.
    pub use_typed: bool,
    /// Propagate the whole typed cloud in one lane-batched replay per
    /// observation step (default; continuous models only). A step the
    /// batched walk cannot replicate bit-for-bit — a lane rejection or a
    /// structure change — re-runs through the per-particle path with the
    /// same seeds, so results never depend on this flag.
    pub use_batched: bool,
    /// Rao-Blackwellized evidence: when the static analyzer certifies the
    /// model as single-site Normal–Normal conjugate
    /// ([`crate::analysis::ModelAnalysis::collapsed_logweights`]), replace
    /// the particle log-evidence estimate with the *exact* collapsed
    /// marginal (zero-variance — every observation weight is the
    /// locally-optimal `log p(y_t | y_{1:t-1})` in closed form). Off by
    /// default: the particle estimate is the quantity the benchmarks and
    /// streaming-update paths are calibrated against.
    pub use_collapsed: bool,
}

impl Default for Smc {
    fn default() -> Self {
        Self {
            n_particles: 256,
            resampler: Resampler::Systematic,
            ess_threshold: 0.5,
            threads: 1,
            use_typed: true,
            use_batched: true,
            use_collapsed: false,
        }
    }
}

/// The cloud an SMC run ended with: typed fast path (plus the boxed
/// template kept for conversion) or boxed fallback.
#[derive(Clone, Debug)]
pub enum SmcCloud {
    Typed {
        cloud: TypedCloud,
        template: UntypedVarInfo,
    },
    Boxed(BoxedCloud),
}

impl SmcCloud {
    pub fn len(&self) -> usize {
        match self {
            SmcCloud::Typed { cloud, .. } => cloud.len(),
            SmcCloud::Boxed(c) => c.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_typed(&self) -> bool {
        matches!(self, SmcCloud::Typed { .. })
    }

    pub fn n_obs(&self) -> usize {
        match self {
            SmcCloud::Typed { cloud, .. } => cloud.n_obs,
            SmcCloud::Boxed(c) => c.n_obs,
        }
    }

    pub fn log_evidence(&self) -> f64 {
        match self {
            SmcCloud::Typed { cloud, .. } => cloud.log_evidence,
            SmcCloud::Boxed(c) => c.log_evidence,
        }
    }

    pub fn ess(&self) -> f64 {
        match self {
            SmcCloud::Typed { cloud, .. } => cloud.ess(),
            SmcCloud::Boxed(c) => c.ess(),
        }
    }

    /// Normalized weights (probabilities).
    pub fn weights(&self) -> Vec<f64> {
        match self {
            SmcCloud::Typed { cloud, .. } => cloud.weights(),
            SmcCloud::Boxed(c) => c.weights(),
        }
    }

    /// Per-particle normalized log-weights.
    pub fn log_weights(&self) -> Vec<f64> {
        match self {
            SmcCloud::Typed { cloud, .. } => {
                cloud.particles.iter().map(|p| p.log_weight).collect()
            }
            SmcCloud::Boxed(c) => c.particles.iter().map(|p| p.log_weight).collect(),
        }
    }

    /// Constrained value of variable `vn` in particle `i`, if traced.
    pub fn value_of(&self, i: usize, vn: &VarName) -> Option<Value> {
        match self {
            SmcCloud::Typed { cloud, .. } => {
                let state = &cloud.particles[i].state;
                state
                    .slots()
                    .iter()
                    .find(|s| &s.vn == vn)
                    .map(|s| state.boxed_value(s))
            }
            SmcCloud::Boxed(c) => c.particles[i].state.get(vn).map(|r| r.value.clone()),
        }
    }

    fn maybe_resample(&mut self, resampler: Resampler, threshold: f64, rng: &mut Xoshiro256pp) -> bool {
        match self {
            SmcCloud::Typed { cloud, .. } => cloud.maybe_resample(resampler, threshold, false, rng),
            SmcCloud::Boxed(c) => c.maybe_resample(resampler, threshold, false, rng),
        }
    }
}

/// Outcome of one SMC run.
pub struct SmcResult {
    /// Final weighted cloud (post last observation; not equalized).
    pub cloud: SmcCloud,
    /// Log-marginal-likelihood estimate `log Ẑ`.
    pub log_evidence: f64,
    /// ESS after each observation step.
    pub ess_trace: Vec<f64>,
    /// Number of resampling passes triggered.
    pub resamples: usize,
    /// Observation steps executed on the typed fast path.
    pub typed_steps: usize,
    /// Mid-sweep demotions to the boxed path (dynamic structure changes;
    /// 0 or 1 for a single sweep — once boxed, a sweep stays boxed).
    pub demotions: usize,
    pub wall_secs: f64,
}

impl Smc {
    pub fn new(n_particles: usize) -> Self {
        Self {
            n_particles,
            ..Smc::default()
        }
    }

    /// Run the filter over every observe statement of `model`.
    pub fn run(&self, model: &dyn Model, seed: u64) -> SmcResult {
        assert!(self.n_particles >= 2);
        assert!(self.ess_threshold > 0.0 && self.ess_threshold <= 1.0);
        let t0 = Instant::now();
        let boxed = BoxedCloud::from_prior(model, self.n_particles, seed, self.threads);
        // specialize after the first full run: every particle must share
        // one layout, otherwise the model is dynamic across particles and
        // the sweep stays boxed
        let state = if self.use_typed {
            match TypedCloud::promote(&boxed) {
                Some((cloud, template)) => {
                    metrics::inc(Counter::TypedPromotions);
                    SmcCloud::Typed { cloud, template }
                }
                None => SmcCloud::Boxed(boxed),
            }
        } else {
            SmcCloud::Boxed(boxed)
        };
        let mut result = self.filter_from(model, state, seed, t0);
        if self.use_collapsed {
            if let SmcCloud::Typed { cloud, .. } = &result.cloud {
                let template = &cloud.particles[0].state;
                if let Some(lz) = crate::analysis::analyze(model, template)
                    .and_then(|a| a.collapsed_logweights(template))
                    .map(|ws| ws.iter().sum::<f64>())
                {
                    result.log_evidence = lz;
                }
            }
        }
        result
    }

    /// Continue a finished (or partially consumed) filter over a model
    /// whose observation record has been **extended** — streaming Bayesian
    /// updating. The cloud's particles, weights and accumulated
    /// log-evidence carry over; the filter re-probes the model for its new
    /// observation horizon and consumes only the appended steps, so each
    /// step's cost is independent of how much history the cloud already
    /// absorbed. New latent variables introduced by the extension (e.g.
    /// fresh states of a state-space model) demote a typed cloud to the
    /// boxed path exactly like a mid-sweep structure change; models whose
    /// latent set is fixed stay typed. `SmcResult.log_evidence` is the
    /// *total* running evidence (old value + the increment from the new
    /// observations). Deterministic in `(cloud, seed)` — pass a distinct
    /// seed per update batch so the fresh steps get fresh RNG streams.
    pub fn resume(&self, model: &dyn Model, mut state: SmcCloud, seed: u64) -> SmcResult {
        let t0 = Instant::now();
        let n_obs_new = match &state {
            SmcCloud::Typed { template, .. } => count_observes(model, template),
            SmcCloud::Boxed(c) => count_observes(model, &c.particles[0].state),
        };
        match &mut state {
            SmcCloud::Typed { cloud, .. } => {
                assert!(
                    n_obs_new >= cloud.step,
                    "streaming update shrank the observation record ({} < {})",
                    n_obs_new,
                    cloud.step
                );
                cloud.n_obs = n_obs_new;
            }
            SmcCloud::Boxed(c) => {
                assert!(
                    n_obs_new >= c.step,
                    "streaming update shrank the observation record ({} < {})",
                    n_obs_new,
                    c.step
                );
                c.n_obs = n_obs_new;
            }
        }
        self.filter_from(model, state, seed, t0)
    }

    /// The shared filter loop: consume observation steps from the cloud's
    /// current position to its horizon. Both [`Smc::run`] (from 0) and
    /// [`Smc::resume`] (from wherever the cached cloud stopped) end here.
    fn filter_from(
        &self,
        model: &dyn Model,
        mut state: SmcCloud,
        seed: u64,
        t0: Instant,
    ) -> SmcResult {
        // master stream: resampling decisions only (serial → deterministic)
        let mut master =
            Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0x5EED));
        let n_obs = state.n_obs();
        let from = match &state {
            SmcCloud::Typed { cloud, .. } => cloud.step,
            SmcCloud::Boxed(c) => c.step,
        };
        let mut ess_trace = Vec::with_capacity(n_obs - from);
        let mut resamples = 0usize;
        let mut typed_steps = 0usize;
        let mut demotions = 0usize;
        for t in from..n_obs {
            state = match state {
                SmcCloud::Typed { mut cloud, template } => {
                    // one K-lane replay for the whole population; `None`
                    // (lane rejection / structure change) falls through to
                    // the per-particle path, which re-runs the *same* step
                    // with the same seeds — bitwise-equal either way
                    let batched = self.use_batched
                        && cloud.particles[0].state.discrete.is_empty()
                        && cloud.advance_batched(model, seed).is_some();
                    if batched {
                        typed_steps += 1;
                        SmcCloud::Typed { cloud, template }
                    } else {
                    match cloud.advance(model, seed, self.threads) {
                        Ok(_) => {
                            typed_steps += 1;
                            SmcCloud::Typed { cloud, template }
                        }
                        Err(LayoutMismatch) => {
                            // roll-back happened inside advance; replay the
                            // step through the boxed path (same RNG streams
                            // → identical to an all-boxed run)
                            demotions += 1;
                            metrics::inc(Counter::TypedDemotions);
                            let mut b = cloud.demote(&template, None);
                            b.advance(model, seed, self.threads)
                                .expect("boxed replay cannot mismatch");
                            SmcCloud::Boxed(b)
                        }
                    }
                    }
                }
                SmcCloud::Boxed(mut b) => {
                    b.advance(model, seed, self.threads)
                        .expect("boxed replay cannot mismatch");
                    SmcCloud::Boxed(b)
                }
            };
            ess_trace.push(state.ess());
            // keep the final cloud weighted: no resample after the last step
            if t + 1 < n_obs
                && state.maybe_resample(self.resampler, self.ess_threshold, &mut master)
            {
                resamples += 1;
                metrics::inc(Counter::ResampleEvents);
            }
        }
        SmcResult {
            log_evidence: state.log_evidence(),
            cloud: state,
            ess_trace,
            resamples,
            typed_steps,
            demotions,
            wall_secs: t0.elapsed().as_secs_f64(),
        }
    }

    /// Run the filter and return an equal-weight [`Chain`]: the final
    /// cloud is resampled to uniform weights and each particle becomes
    /// one constrained-space draw (`len == n_particles`). The chain's
    /// `stats.log_evidence` carries the evidence estimate.
    pub fn sample_chain(&self, model: &dyn Model, seed: u64) -> Chain {
        let result = self.run(model, seed);
        self.chain_from_result(model, &result, seed)
    }

    /// Convert a finished filter into an equal-weight [`Chain`] without
    /// consuming the cloud — the serving runtime keeps the [`SmcResult`]
    /// (for streaming updates) *and* drains draws from it. Same resample
    /// + full-trace-scoring pass [`Smc::sample_chain`] performs.
    pub fn chain_from_result(&self, model: &dyn Model, result: &SmcResult, seed: u64) -> Chain {
        let t0 = Instant::now();
        let mut master =
            Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0xCA1A));
        let weights = result.cloud.weights();
        let ancestors = self
            .resampler
            .ancestors(&weights, self.n_particles, &mut master);

        // full-trace scoring of a typed cloud rides the compiled static
        // replay when the model proves stable (one compile per chain; the
        // particles share one layout, so a program compiled against any
        // particle serves them all). Particles whose discrete sub-trace
        // drifted from the compile snapshot demote per score — to the
        // fused dynamic walk, the family the program is bitwise-validated
        // against. Models that do not promote keep the replay walk.
        let prog = match &result.cloud {
            SmcCloud::Typed { cloud, .. } => {
                crate::model::compiled::try_compile(model, &cloud.particles[0].state)
            }
            SmcCloud::Boxed(_) => None,
        };

        // resampling duplicates ancestors heavily on peaked posteriors:
        // replay/convert each unique ancestor once, push its row k times
        let mut rows: HashMap<usize, (Vec<f64>, f64)> = HashMap::new();
        let mut chain: Option<Chain> = None;
        for &a in &ancestors {
            if !rows.contains_key(&a) {
                let (names, row, lp) = match &result.cloud {
                    SmcCloud::Typed { cloud, .. } => {
                        let state = &cloud.particles[a].state;
                        match &prog {
                            Some(p) if p.matches_discrete(state) => {
                                // flat compiled scoring straight off the
                                // particle's buffers — `unconstrained` is
                                // kept in sync by every replay write
                                let lp = p.logp(state, &state.unconstrained, Context::Default);
                                (state.column_names(), state.row(), lp)
                            }
                            Some(_) => {
                                metrics::inc(Counter::StaticDemotions);
                                let lp = crate::model::typed_logp_fused(
                                    model,
                                    state,
                                    &state.unconstrained,
                                    Context::Default,
                                );
                                (state.column_names(), state.row(), lp)
                            }
                            None => {
                                // full-joint evaluation directly over the
                                // flat buffers (nothing flagged → pure
                                // replay; Default context scores priors +
                                // likelihood, matching `sample_run` bit
                                // for bit)
                                let mut state = state.clone();
                                let mut rng0 = Xoshiro256pp::seed_from_u64(0);
                                let rep = TypedReplayExecutor::run(
                                    model,
                                    &mut rng0,
                                    &mut state,
                                    Context::Default,
                                    ReplayScope::Unscoped,
                                );
                                (state.column_names(), state.row(), rep.delta_logw)
                            }
                        }
                    }
                    SmcCloud::Boxed(c) => {
                        let mut trace = c.particles[a].state.clone();
                        // full-joint replay (values all present → pure replay)
                        let lp = sample_run(model, &mut master, &mut trace, Context::Default);
                        let tvi = TypedVarInfo::from_untyped(&trace);
                        (tvi.column_names(), tvi.row(), lp)
                    }
                };
                if chain.is_none() {
                    chain = Some(Chain::new(names));
                }
                rows.insert(a, (row, lp));
            }
            let (row, lp) = &rows[&a];
            chain
                .as_mut()
                .expect("chain initialized with first ancestor")
                .push(row.clone(), *lp);
        }
        let mut chain = chain.expect("SMC produced an empty cloud");
        let wall_secs = result.wall_secs + t0.elapsed().as_secs_f64();
        chain.stats = SamplerStats {
            accept_rate: 1.0,
            wall_secs,
            // SMC has no warmup phase: the whole pass is "sampling"
            sampling_secs: wall_secs,
            log_evidence: result.log_evidence,
            ..SamplerStats::default()
        };
        chain
    }
}

/// Conditional-SMC sweep configuration (the Particle-Gibbs kernel).
#[derive(Clone, Copy, Debug)]
pub struct Csmc {
    pub n_particles: usize,
    /// Multinomial is the safe scheme for the conditional filter and the
    /// Particle-Gibbs default.
    pub resampler: Resampler,
    /// Resample when `ESS < ess_threshold · N`.
    pub ess_threshold: f64,
    /// Ancestor sampling (PGAS): at every resampling step, also resample
    /// the *retained* particle's ancestor index, weighting each candidate
    /// by `W_i · p(reference future | candidate prefix)`. Breaks the path
    /// degeneracy that freezes the early part of the retained trajectory,
    /// at the cost of one evaluation replay per particle per resampling
    /// step (Lindsten, Jordan & Schön 2014).
    pub ancestor_sampling: bool,
}

impl Csmc {
    pub fn new(n_particles: usize) -> Self {
        Self {
            n_particles,
            resampler: Resampler::Multinomial,
            ess_threshold: 0.5,
            ancestor_sampling: false,
        }
    }
}

/// One conditional-SMC (Particle-Gibbs) sweep: run an N-particle filter
/// in which particle 0 is pinned to the `reference` trajectory's values
/// of the `scope` variables (all other variables replay exactly in every
/// particle), then draw one particle from the final weights. The returned
/// trace is a sample from a Markov kernel that leaves the conditional
/// posterior of `scope` invariant (Andrieu, Doucet & Holenstein 2010).
///
/// `n_obs` is the model's observe-statement count: pass
/// `Some(crate::particle::count_observes(model, reference))` computed
/// once when sweeping in a loop (Gibbs does), or `None` to probe here.
///
/// `typed_template` switches the sweep onto the typed fast path: when the
/// reference still fits the template's layout, all N particles run as
/// flat-buffer forks; a mid-sweep structure change demotes to the boxed
/// path and finishes the sweep there. `None` (or a stale template) runs
/// boxed.
#[allow(clippy::too_many_arguments)]
pub fn csmc_sweep(
    model: &dyn Model,
    reference: &UntypedVarInfo,
    scope: &[VarName],
    cfg: &Csmc,
    seed: u64,
    n_obs: Option<usize>,
    typed_template: Option<&TypedVarInfo>,
) -> UntypedVarInfo {
    let n_obs = n_obs.unwrap_or_else(|| count_observes(model, reference));
    let mut master = Xoshiro256pp::seed_from_u64(particle_seed(seed, usize::MAX / 2, 0xC5bC));
    if let Some(template) = typed_template {
        if let Some(mut cloud) =
            TypedCloud::conditional_typed(template, reference, scope, cfg.n_particles, n_obs)
        {
            match csmc_loop(&mut cloud, model, cfg, seed, &mut master) {
                Ok(()) => {
                    let k = cloud.select(&mut master);
                    return cloud.particles[k].state.to_untyped(reference);
                }
                Err(LayoutMismatch) => {
                    // finish the sweep on the boxed path, same streams
                    let mut boxed = cloud.demote(reference, Some(scope.to_vec()));
                    csmc_loop(&mut boxed, model, cfg, seed, &mut master)
                        .expect("boxed replay cannot mismatch");
                    let k = boxed.select(&mut master);
                    return boxed.particles.swap_remove(k).state;
                }
            }
        }
    }
    let mut cloud = BoxedCloud::conditional(reference, scope, cfg.n_particles, n_obs);
    csmc_loop(&mut cloud, model, cfg, seed, &mut master)
        .expect("boxed replay cannot mismatch");
    let k = cloud.select(&mut master);
    cloud.particles.swap_remove(k).state
}

/// The conditional filter loop, written once for both representations.
/// Resumes from `cloud.step`, so a demoted cloud continues mid-sweep.
fn csmc_loop<S: ParticleState>(
    cloud: &mut ParticleCloud<S>,
    model: &dyn Model,
    cfg: &Csmc,
    seed: u64,
    master: &mut Xoshiro256pp,
) -> Result<(), LayoutMismatch> {
    while cloud.step < cloud.n_obs {
        let t = cloud.step;
        cloud.advance(model, seed, 1)?;
        if t + 1 < cloud.n_obs && cloud.ess() < cfg.ess_threshold * cloud.len() as f64 {
            // PGAS: pick the retained path's new ancestry from the
            // pre-resampling generation…
            let new_reference = if cfg.ancestor_sampling {
                Some(cloud.ancestor_sample_reference(model, master))
            } else {
                None
            };
            cloud.resample(cfg.resampler, true, master);
            // …and splice it in after the children forked, so they forked
            // from the original generation (Lindsten et al. 2014, step 2b)
            if let Some(reference) = new_reference {
                cloud.particles[0].state = reference;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use rand_core::RngCore;

    use super::*;
    use crate::prelude::*;
    use crate::util::stats;

    model! {
        /// Conjugate Normal–Normal: m ~ N(mu0, tau0); y_t ~ N(m, sigma).
        pub NormalNormal {
            y: Vec<f64>,
            mu0: f64,
            tau0: f64,
            sigma: f64,
        }
        fn body<T>(this, api) {
            let m = tilde!(api, m ~ Normal(c(this.mu0), c(this.tau0)));
            for &yi in &this.y {
                obs!(api, yi => Normal(m, c(this.sigma)));
            }
        }
    }

    /// Closed-form log-evidence by sequential 1-D conjugate updates:
    /// log p(y) = Σ_t log N(y_t; μ_{t−1}, √(σ² + τ²_{t−1})).
    pub fn analytic_log_evidence(y: &[f64], mu0: f64, tau0: f64, sigma: f64) -> f64 {
        let (mut mu, mut tau2) = (mu0, tau0 * tau0);
        let s2 = sigma * sigma;
        let mut lz = 0.0;
        for &yt in y {
            let pred_var = s2 + tau2;
            lz += Normal::new(mu, pred_var.sqrt()).logpdf(yt);
            // posterior update
            let k = tau2 / pred_var;
            mu += k * (yt - mu);
            tau2 *= 1.0 - k;
        }
        lz
    }

    fn demo_data() -> Vec<f64> {
        // mild data near the prior mean: low weight variance
        vec![0.4, -0.1, 0.7, 0.2, -0.3, 0.5]
    }

    fn demo_model() -> NormalNormal {
        NormalNormal {
            y: demo_data(),
            mu0: 0.0,
            tau0: 1.0,
            sigma: 1.0,
        }
    }

    #[test]
    fn smc_recovers_analytic_evidence_within_two_percent() {
        let y = demo_data();
        let m = demo_model();
        let want = analytic_log_evidence(&y, 0.0, 1.0, 1.0);
        let smc = Smc {
            n_particles: 4096,
            ..Smc::default()
        };
        let out = smc.run(&m, 42);
        assert_eq!(out.ess_trace.len(), y.len());
        // the static model must have run typed the whole way
        assert!(out.cloud.is_typed());
        assert_eq!(out.typed_steps, y.len());
        assert_eq!(out.demotions, 0);
        assert!(
            ((out.log_evidence - want) / want).abs() < 0.02,
            "SMC log-evidence {} vs analytic {want}",
            out.log_evidence
        );
    }

    #[test]
    fn typed_and_boxed_smc_agree_bitwise() {
        let m = demo_model();
        let typed = Smc {
            n_particles: 256,
            ..Smc::default()
        }
        .run(&m, 91);
        let boxed = Smc {
            n_particles: 256,
            use_typed: false,
            ..Smc::default()
        }
        .run(&m, 91);
        assert!(typed.cloud.is_typed());
        assert!(!boxed.cloud.is_typed());
        assert_eq!(typed.log_evidence.to_bits(), boxed.log_evidence.to_bits());
        assert_eq!(typed.resamples, boxed.resamples);
        let (lt, lb) = (typed.cloud.log_weights(), boxed.cloud.log_weights());
        let vn = VarName::new("m");
        for i in 0..256 {
            assert_eq!(lt[i].to_bits(), lb[i].to_bits());
            assert_eq!(typed.cloud.value_of(i, &vn), boxed.cloud.value_of(i, &vn));
        }
    }

    #[test]
    fn batched_and_per_particle_smc_agree_bitwise() {
        // the lane-batched cloud replay must be invisible in the results:
        // same seeds, bitwise-equal evidence, weights and values
        let m = demo_model();
        let batched = Smc {
            n_particles: 128,
            ..Smc::default()
        }
        .run(&m, 77);
        let plain = Smc {
            n_particles: 128,
            use_batched: false,
            ..Smc::default()
        }
        .run(&m, 77);
        assert!(batched.cloud.is_typed() && plain.cloud.is_typed());
        assert_eq!(batched.log_evidence.to_bits(), plain.log_evidence.to_bits());
        assert_eq!(batched.resamples, plain.resamples);
        let (lb, lp) = (batched.cloud.log_weights(), plain.cloud.log_weights());
        let vn = VarName::new("m");
        for i in 0..128 {
            assert_eq!(lb[i].to_bits(), lp[i].to_bits());
            assert_eq!(batched.cloud.value_of(i, &vn), plain.cloud.value_of(i, &vn));
        }
    }

    #[test]
    fn smc_posterior_matches_conjugate_posterior() {
        let y = demo_data();
        let m = demo_model();
        // conjugate posterior of m
        let n = y.len() as f64;
        let post_var = 1.0 / (1.0 + n);
        let post_mean = post_var * y.iter().sum::<f64>();
        let chain = Smc {
            n_particles: 2048,
            ..Smc::default()
        }
        .sample_chain(&m, 7);
        assert_eq!(chain.len(), 2048);
        let ms = chain.column("m").unwrap();
        assert!(
            (stats::mean(&ms) - post_mean).abs() < 0.05,
            "{} vs {post_mean}",
            stats::mean(&ms)
        );
        assert!(
            (stats::variance(&ms) - post_var).abs() < 0.05,
            "{} vs {post_var}",
            stats::variance(&ms)
        );
        assert!(chain.stats.log_evidence.is_finite());
    }

    #[test]
    fn parallel_propagation_is_bitwise_deterministic() {
        let m = demo_model();
        let run = |threads: usize| {
            let smc = Smc {
                n_particles: 512,
                threads,
                ..Smc::default()
            };
            smc.run(&m, 1234)
        };
        let serial = run(1);
        let parallel = run(4);
        assert_eq!(
            serial.log_evidence.to_bits(),
            parallel.log_evidence.to_bits(),
            "evidence must be bitwise identical across thread counts"
        );
        let vn = VarName::new("m");
        let (ls, lp) = (serial.cloud.log_weights(), parallel.cloud.log_weights());
        for i in 0..512 {
            assert_eq!(ls[i].to_bits(), lp[i].to_bits());
            assert_eq!(serial.cloud.value_of(i, &vn), parallel.cloud.value_of(i, &vn));
        }
        // and fully reproducible for the same seed
        let again = run(4);
        assert_eq!(parallel.log_evidence.to_bits(), again.log_evidence.to_bits());
    }

    #[test]
    fn csmc_sweep_is_a_valid_conditional_kernel() {
        // Iterated CSMC on the conjugate model must traverse the
        // posterior of m: run a short PG chain by hand and check moments.
        let y = demo_data();
        let m = demo_model();
        let n = y.len() as f64;
        let post_var = 1.0 / (1.0 + n);
        let post_mean = post_var * y.iter().sum::<f64>();

        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let mut state = crate::model::init_trace(&m, &mut rng);
        let template = TypedVarInfo::from_untyped(&state);
        let scope = [VarName::new("m")];
        let n_obs = Some(crate::particle::count_observes(&m, &state));
        let cfg = Csmc::new(16);
        let mut draws = Vec::new();
        for it in 0..3000 {
            state = csmc_sweep(&m, &state, &scope, &cfg, rng.next_u64(), n_obs, Some(&template));
            if it >= 200 {
                draws.push(state.get(&VarName::new("m")).unwrap().value.as_f64().unwrap());
            }
        }
        assert!(
            (stats::mean(&draws) - post_mean).abs() < 0.06,
            "PG mean {} vs {post_mean}",
            stats::mean(&draws)
        );
        assert!(
            (stats::variance(&draws) - post_var).abs() < 0.06,
            "PG var {} vs {post_var}",
            stats::variance(&draws)
        );
    }

    #[test]
    fn csmc_with_ancestor_sampling_targets_the_same_posterior() {
        // PGAS must leave the same conditional posterior invariant; only
        // the mixing speed differs. Same moment checks as the plain sweep.
        let y = demo_data();
        let m = demo_model();
        let n = y.len() as f64;
        let post_var = 1.0 / (1.0 + n);
        let post_mean = post_var * y.iter().sum::<f64>();

        let mut rng = Xoshiro256pp::seed_from_u64(17);
        let mut state = crate::model::init_trace(&m, &mut rng);
        let template = TypedVarInfo::from_untyped(&state);
        let scope = [VarName::new("m")];
        let n_obs = Some(crate::particle::count_observes(&m, &state));
        let cfg = Csmc {
            ancestor_sampling: true,
            ..Csmc::new(16)
        };
        let mut draws = Vec::new();
        for it in 0..2500 {
            state = csmc_sweep(&m, &state, &scope, &cfg, rng.next_u64(), n_obs, Some(&template));
            if it >= 200 {
                draws.push(state.get(&VarName::new("m")).unwrap().value.as_f64().unwrap());
            }
        }
        assert!(
            (stats::mean(&draws) - post_mean).abs() < 0.06,
            "PGAS mean {} vs {post_mean}",
            stats::mean(&draws)
        );
        assert!(
            (stats::variance(&draws) - post_var).abs() < 0.07,
            "PGAS var {} vs {post_var}",
            stats::variance(&draws)
        );
    }
}
