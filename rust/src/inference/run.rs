//! High-level sampling drivers: one call from (model, density, sampler
//! config) to a constrained-space [`Chain`], plus multi-chain parallel
//! execution on the thread pool.

use crate::chain::{Chain, MultiChain};
use crate::gradient::LogDensity;
use crate::model::{sample_run, Model};
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_map;
use crate::varinfo::{TypedVarInfo, UntypedVarInfo};

use crate::vi::Advi;

use super::{Hmc, Nuts, RwMh, Smc};

/// Which sampler drives a chain. The gradient/density samplers (HMC,
/// NUTS, MH, ADVI) run against a [`LogDensity`]; [`SamplerKind::Smc`] is
/// a model-space particle sampler and is driven by [`sample_smc_chain`].
/// [`SamplerKind::Advi`] is not MCMC at all: it fits a variational
/// approximation and the "chain" is `iters` independent draws from it
/// (`warmup` is ignored — the optimization budget lives in
/// [`Advi::max_iters`]).
#[derive(Clone, Debug)]
pub enum SamplerKind {
    Hmc(Hmc),
    Nuts(Nuts),
    RwMh(RwMh),
    Smc(Smc),
    Advi(Advi),
}

/// Run one chain: sample unconstrained draws from `ld`, convert them to
/// constrained rows through a working copy of `tvi`.
pub fn sample_chain(
    ld: &dyn LogDensity,
    tvi: &TypedVarInfo,
    kind: &SamplerKind,
    warmup: usize,
    iters: usize,
    seed: u64,
) -> Chain {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let theta0 = tvi.unconstrained.clone();
    // scope the telemetry shard to this chain run: drop whatever earlier
    // activity left on this thread, then drain what the sampler counted
    let _ = crate::obs::metrics::take_local();
    let raw = match kind {
        SamplerKind::Hmc(h) => h.sample(ld, &theta0, warmup, iters, &mut rng),
        SamplerKind::Nuts(n) => n.sample(ld, &theta0, warmup, iters, &mut rng),
        SamplerKind::RwMh(m) => m.sample(ld, &theta0, warmup, iters, &mut rng),
        SamplerKind::Advi(a) => a.sample(ld, &theta0, warmup, iters, &mut rng),
        SamplerKind::Smc(_) => panic!(
            "SMC re-executes the model and cannot run from a LogDensity; \
             use inference::sample_smc_chain(model, &smc, seed)"
        ),
    };
    let mut chain = raw_to_chain(&raw, tvi);
    chain.stats.metrics = crate::obs::metrics::take_local();
    chain
}

/// Convert unconstrained [`RawDraws`] to a constrained-space [`Chain`]
/// through a working copy of `tvi` — the one row-conversion path every
/// density-space sampler (and the VI bench) shares.
pub fn raw_to_chain(raw: &super::RawDraws, tvi: &TypedVarInfo) -> Chain {
    let mut work = tvi.clone();
    let mut chain = Chain::new(work.column_names());
    for (theta, lp) in raw.thetas.iter().zip(&raw.logps) {
        work.set_unconstrained(theta);
        chain.push(work.row(), *lp);
    }
    chain.stats = raw.stats.clone();
    chain
}

/// Run `n_chains` chains in parallel. `make` builds the per-chain state
/// (model/density may be shared via references in the closure).
pub fn sample_chains<F>(n_chains: usize, threads: usize, make: F) -> MultiChain
where
    F: Fn(usize) -> Chain + Send + Sync + 'static,
{
    MultiChain::new(parallel_map(threads, n_chains, make))
}

/// Run `lanes` chains whose gradient passes are fused into K-lane batched
/// evaluations through a [`super::lanes::LaneGang`]: every chain keeps its
/// own RNG stream (`seed + lane`), step size and adaptation, so each
/// chain's draws are bit-identical to [`sample_chain`] with the same seed
/// — only wall-clock changes. Gradient-driven samplers only (HMC/NUTS);
/// chains retire from the gang independently as they finish.
pub fn sample_chains_batched(
    ld: &dyn LogDensity,
    tvi: &TypedVarInfo,
    kind: &SamplerKind,
    warmup: usize,
    iters: usize,
    seed: u64,
    lanes: usize,
) -> MultiChain {
    assert!(
        matches!(kind, SamplerKind::Hmc(_) | SamplerKind::Nuts(_)),
        "lane-batched chains need a gradient-driven sampler (HMC/NUTS)"
    );
    let gang = super::lanes::LaneGang::new(ld, lanes);
    let chains = std::thread::scope(|s| {
        let handles: Vec<_> = (0..lanes)
            .map(|l| {
                let gang = &gang;
                s.spawn(move || {
                    let lane_ld = super::lanes::LaneDensity::new(gang, l);
                    let chain = sample_chain(&lane_ld, tvi, kind, warmup, iters, seed + l as u64);
                    gang.leave(l);
                    chain
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("lane chain thread panicked"))
            .collect()
    });
    MultiChain::new(chains)
}

/// Run one SMC "chain": a full particle-filter pass over the model's
/// observations, returned as an equal-weight chain of `n_particles`
/// draws whose `stats.log_evidence` carries the marginal-likelihood
/// estimate (see [`crate::inference::smc`]).
pub fn sample_smc_chain(model: &dyn Model, smc: &Smc, seed: u64) -> Chain {
    let _ = crate::obs::metrics::take_local();
    let mut chain = smc.sample_chain(model, seed);
    chain.stats.metrics = crate::obs::metrics::take_local();
    chain
}

/// Sample from the prior by repeated fresh model runs (one trace rebuild
/// per draw — the dynamic path; used for prior predictive checks).
pub fn sample_prior(model: &dyn Model, iters: usize, seed: u64) -> Chain {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut vi = UntypedVarInfo::new();
    let _ = sample_run(model, &mut rng, &mut vi, crate::context::Context::Default);
    let tvi = TypedVarInfo::from_untyped(&vi);
    let mut chain = Chain::new(tvi.column_names());
    // first draw
    chain.push(tvi.row(), vi.logp);
    for _ in 1..iters {
        vi.flag_all_resample();
        let lp = sample_run(model, &mut rng, &mut vi, crate::context::Context::Default);
        let t = TypedVarInfo::from_untyped(&vi);
        chain.push(t.row(), lp);
    }
    chain
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::std_normal_density;
    use crate::prelude::*;
    use crate::util::stats;
    use std::sync::Arc;

    #[test]
    fn chain_is_constrained_space() {
        model! {
            pub PosModel {
                dummy: f64,
            }
            fn body<T>(this, api) {
                let _ = this.dummy;
                let _s = tilde!(api, s ~ Exponential(c(1.0)));
            }
        }
        let m = PosModel { dummy: 0.0 };
        let mut rng = Xoshiro256pp::seed_from_u64(31);
        let tvi = crate::model::init_typed(&m, &mut rng);
        let ld = crate::gradient::NativeDensity::new(&m, &tvi, crate::gradient::Backend::Forward);
        let chain = sample_chain(
            &ld,
            &tvi,
            &SamplerKind::Hmc(Hmc::default()),
            500,
            4000,
            7,
        );
        let s = chain.column("s").unwrap();
        assert!(s.iter().all(|&v| v > 0.0), "constrained draws must be positive");
        // Exponential(1) has mean 1
        assert!((stats::mean(&s) - 1.0).abs() < 0.1, "{}", stats::mean(&s));
    }

    #[test]
    fn parallel_chains_are_distinct_and_consistent() {
        let tvi = {
            model! {
                pub StdNorm { dummy: f64, }
                fn body<T>(this, api) {
                    let _ = this.dummy;
                    let _x = tilde!(api, x ~ Normal(c(0.0), c(1.0)));
                }
            }
            let m = StdNorm { dummy: 0.0 };
            let mut rng = Xoshiro256pp::seed_from_u64(32);
            crate::model::init_typed(&m, &mut rng)
        };
        let tvi = Arc::new(tvi);
        let t2 = Arc::clone(&tvi);
        let mc = sample_chains(4, 4, move |i| {
            let ld = std_normal_density(1);
            sample_chain(
                &ld,
                &t2,
                &SamplerKind::RwMh(RwMh::default()),
                1000,
                4000,
                100 + i as u64,
            )
        });
        assert_eq!(mc.chains.len(), 4);
        let rhat = mc.rhat("x").unwrap();
        assert!((rhat - 1.0).abs() < 0.05, "R̂ = {rhat}");
        // distinct seeds → distinct draws
        assert_ne!(mc.chains[0].rows()[0], mc.chains[1].rows()[0]);
    }

    #[test]
    fn smc_chain_driver_produces_equal_weight_draws() {
        model! {
            pub SmcDemo { y: Vec<f64>, }
            fn body<T>(this, api) {
                let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
                for &yi in &this.y {
                    obs!(api, yi => Normal(m, c(1.0)));
                }
            }
        }
        let m = SmcDemo { y: vec![0.2, -0.4, 0.1] };
        let smc = Smc {
            n_particles: 256,
            ..Smc::default()
        };
        let chain = sample_smc_chain(&m, &smc, 13);
        assert_eq!(chain.len(), 256);
        assert!(chain.stats.log_evidence.is_finite());
        let ms = chain.column("m").unwrap();
        // conjugate posterior mean: Σy / (n + 1)
        assert!((stats::mean(&ms) + 0.025).abs() < 0.15, "{}", stats::mean(&ms));
    }

    #[test]
    fn advi_chain_is_constrained_space_and_carries_elbo() {
        // ADVI plugs into the same chain driver as the MCMC samplers:
        // draws come back in constrained space with the ELBO in
        // stats.log_evidence.
        model! {
            pub PosVi {
                dummy: f64,
            }
            fn body<T>(this, api) {
                let _ = this.dummy;
                let _s = tilde!(api, s ~ Exponential(c(1.0)));
            }
        }
        let m = PosVi { dummy: 0.0 };
        let mut rng = Xoshiro256pp::seed_from_u64(33);
        let tvi = crate::model::init_typed(&m, &mut rng);
        let ld = crate::gradient::NativeDensity::fused(&m, &tvi);
        let chain = sample_chain(
            &ld,
            &tvi,
            &SamplerKind::Advi(crate::vi::Advi::default()),
            0,
            4000,
            17,
        );
        assert_eq!(chain.len(), 4000);
        let s = chain.column("s").unwrap();
        assert!(s.iter().all(|&v| v > 0.0), "constrained draws must be positive");
        // Exponential(1) has mean 1; the Gaussian-in-log-space fit is
        // approximate, so the check is loose
        assert!((stats::mean(&s) - 1.0).abs() < 0.35, "{}", stats::mean(&s));
        // the ELBO lower-bounds the log evidence (0 for a normalized
        // prior); it is a noisy MC estimate, so the bound check is loose
        let elbo = chain.stats.log_evidence;
        assert!(elbo.is_finite() && elbo < 0.5 && elbo > -2.0, "elbo = {elbo}");
    }

    #[test]
    fn prior_sampling_matches_prior_moments() {
        model! {
            pub PriorDemo { dummy: f64, }
            fn body<T>(this, api) {
                let _ = this.dummy;
                let _a = tilde!(api, a ~ Gamma(c(3.0), c(2.0)));
                let _b = tilde!(api, b ~ Beta(c(2.0), c(2.0)));
            }
        }
        let m = PriorDemo { dummy: 0.0 };
        let chain = sample_prior(&m, 20_000, 5);
        let a = chain.column("a").unwrap();
        let b = chain.column("b").unwrap();
        assert!((stats::mean(&a) - 1.5).abs() < 0.05, "{}", stats::mean(&a));
        assert!((stats::mean(&b) - 0.5).abs() < 0.02, "{}", stats::mean(&b));
    }
}
