//! No-U-Turn Sampler (Hoffman & Gelman 2014), multinomial variant with
//! dual-averaging step-size adaptation — AdvancedHMC.jl's default, included
//! beyond the paper's static-HMC benchmarks as the "production" sampler.

use rand_core::RngCore;

use crate::chain::SamplerStats;
use crate::gradient::LogDensity;
use crate::util::rng::Rng;

use super::adapt::{DualAveraging, WelfordVar};
use super::RawDraws;

/// NUTS configuration.
#[derive(Clone, Debug)]
pub struct Nuts {
    pub step_size: f64,
    pub max_depth: usize,
    pub target_accept: f64,
    pub adapt_mass: bool,
    /// Probe a starting ε with the warmup adapter's doubling heuristic
    /// before dual averaging takes over.
    pub init_step_size: bool,
}

impl Default for Nuts {
    fn default() -> Self {
        Self {
            step_size: 0.1,
            max_depth: 10,
            target_accept: 0.8,
            adapt_mass: true,
            init_step_size: false,
        }
    }
}

#[derive(Clone)]
struct State {
    theta: Vec<f64>,
    p: Vec<f64>,
    grad: Vec<f64>,
    lp: f64,
}

struct Tree {
    minus: State,
    plus: State,
    /// multinomial-sampled representative of this subtree
    sample: State,
    /// log of the subtree weight Σ exp(−H)
    log_w: f64,
    /// sum of min(1, exp(−ΔH)) over leaves (for adaptation)
    alpha_sum: f64,
    n_leaves: f64,
    turning_or_diverged: bool,
}

impl Nuts {
    pub fn sample<R: RngCore>(
        &self,
        ld: &dyn LogDensity,
        theta0: &[f64],
        warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> RawDraws {
        let dim = ld.dim();
        let t_start = std::time::Instant::now();
        let mut probe_evals: u64 = 0;
        let mut eps = self.step_size;
        if self.init_step_size {
            let (probed, evals) =
                super::adapt::find_initial_step_size(ld, theta0, self.step_size, rng);
            eps = probed;
            probe_evals = evals;
        }
        let mut da = DualAveraging::new(eps, self.target_accept);
        let mut mass_est = WelfordVar::new(dim);
        let mut inv_mass: Vec<f64> = vec![1.0; dim];

        let mut grad0 = vec![0.0; dim];
        let lp0 = ld.logp_grad_into(theta0, &mut grad0);
        assert!(lp0.is_finite(), "NUTS initialized at zero-probability point");
        let mut n_grad: u64 = 1 + probe_evals;
        let mut current = State {
            theta: theta0.to_vec(),
            p: vec![0.0; dim],
            grad: grad0,
            lp: lp0,
        };

        let mut thetas = Vec::with_capacity(iters);
        let mut logps = Vec::with_capacity(iters);
        let mut divergences = 0usize;
        let mut accept_stat_sum = 0.0;

        for it in 0..warmup + iters {
            for i in 0..dim {
                current.p[i] = rng.normal() / inv_mass[i].sqrt();
            }
            let h0 = hamiltonian(&current, &inv_mass);

            let mut minus = current.clone();
            let mut plus = current.clone();
            let mut sample = current.clone();
            // All weights are normalized relative to the initial energy:
            // the starting state has weight exp(h0 − h0) = 1.
            let mut log_w = 0.0;
            let mut depth = 0;
            let mut turning = false;
            let mut alpha_sum = 0.0;
            let mut n_leaves = 0.0;

            while depth < self.max_depth && !turning {
                let go_right = rng.bernoulli(0.5);
                let sub = if go_right {
                    build_tree(
                        ld, &plus, 1.0, depth, eps, h0, &inv_mass, rng, &mut n_grad,
                    )
                } else {
                    build_tree(
                        ld, &minus, -1.0, depth, eps, h0, &inv_mass, rng, &mut n_grad,
                    )
                };
                alpha_sum += sub.alpha_sum;
                n_leaves += sub.n_leaves;
                if sub.turning_or_diverged {
                    if sub.alpha_sum == 0.0 && sub.n_leaves <= 1.0 {
                        divergences += 1;
                    }
                    break;
                }
                // multinomial merge: accept subtree sample with prob w'/(w+w')
                let log_sum = log_add(log_w, sub.log_w);
                if rng.uniform_pos().ln() < sub.log_w - log_sum {
                    sample = sub.sample.clone();
                }
                log_w = log_sum;
                if go_right {
                    plus = sub.plus;
                } else {
                    minus = sub.minus;
                }
                turning = is_turning(&minus, &plus, &inv_mass);
                depth += 1;
            }

            current = sample.clone();
            let accept_stat = if n_leaves > 0.0 {
                alpha_sum / n_leaves
            } else {
                0.0
            };
            accept_stat_sum += accept_stat;

            if it < warmup {
                eps = da.update(accept_stat);
                if self.adapt_mass {
                    mass_est.push(&current.theta);
                    if mass_est.count() > 50 {
                        inv_mass = mass_est.variance();
                    }
                }
                if it + 1 == warmup {
                    eps = da.finalized();
                }
            } else {
                thetas.push(current.theta.clone());
                logps.push(current.lp);
            }
        }

        RawDraws {
            thetas,
            logps,
            stats: SamplerStats {
                accept_rate: accept_stat_sum / (warmup + iters) as f64,
                divergences,
                step_size: eps,
                n_grad_evals: n_grad,
                wall_secs: t_start.elapsed().as_secs_f64(),
                ..SamplerStats::default()
            },
        }
    }
}

fn hamiltonian(s: &State, inv_mass: &[f64]) -> f64 {
    let ke: f64 = 0.5
        * s.p
            .iter()
            .zip(inv_mass)
            .map(|(&pi, &im)| pi * pi * im)
            .sum::<f64>();
    -s.lp + ke
}

fn log_add(a: f64, b: f64) -> f64 {
    crate::util::math::log_add_exp(a, b)
}

fn leapfrog(ld: &dyn LogDensity, s: &State, dir: f64, eps: f64, inv_mass: &[f64]) -> State {
    let dim = s.theta.len();
    let e = dir * eps;
    let mut p = s.p.clone();
    let mut theta = s.theta.clone();
    for i in 0..dim {
        p[i] += 0.5 * e * s.grad[i];
        theta[i] += e * p[i] * inv_mass[i];
    }
    // tree states own their (stored) buffers, so this allocation is
    // inherent to NUTS's tree construction; `logp_grad_into` writes into
    // it in place, keeping the gradient *engine* allocation-free (the
    // fully allocation-free leapfrog loop lives in static HMC)
    let mut grad = vec![0.0; dim];
    let lp = ld.logp_grad_into(&theta, &mut grad);
    for i in 0..dim {
        p[i] += 0.5 * e * grad[i];
    }
    State { theta, p, grad, lp }
}

fn is_turning(minus: &State, plus: &State, inv_mass: &[f64]) -> bool {
    let mut dot_m = 0.0;
    let mut dot_p = 0.0;
    for i in 0..minus.theta.len() {
        let dq = plus.theta[i] - minus.theta[i];
        dot_m += dq * minus.p[i] * inv_mass[i];
        dot_p += dq * plus.p[i] * inv_mass[i];
    }
    dot_m < 0.0 || dot_p < 0.0
}

#[allow(clippy::too_many_arguments)]
fn build_tree<R: RngCore>(
    ld: &dyn LogDensity,
    start: &State,
    dir: f64,
    depth: usize,
    eps: f64,
    h0: f64,
    inv_mass: &[f64],
    rng: &mut R,
    n_grad: &mut u64,
) -> Tree {
    if depth == 0 {
        let s = leapfrog(ld, start, dir, eps, inv_mass);
        *n_grad += 1;
        let h = hamiltonian(&s, inv_mass);
        let dh = h0 - h;
        let diverged = !dh.is_finite() || dh < -1000.0;
        let alpha = if dh.is_finite() { dh.exp().min(1.0) } else { 0.0 };
        return Tree {
            minus: s.clone(),
            plus: s.clone(),
            sample: s,
            log_w: if diverged { f64::NEG_INFINITY } else { dh },
            alpha_sum: alpha,
            n_leaves: 1.0,
            turning_or_diverged: diverged,
        };
    }
    let first = build_tree(ld, start, dir, depth - 1, eps, h0, inv_mass, rng, n_grad);
    if first.turning_or_diverged {
        return first;
    }
    let cont = if dir > 0.0 { &first.plus } else { &first.minus };
    let second = build_tree(ld, cont, dir, depth - 1, eps, h0, inv_mass, rng, n_grad);
    let log_w = log_add(first.log_w, second.log_w);
    let sample = if !second.turning_or_diverged
        && rng.uniform_pos().ln() < second.log_w - log_w
    {
        second.sample.clone()
    } else {
        first.sample.clone()
    };
    let (minus, plus) = if dir > 0.0 {
        (first.minus, second.plus.clone())
    } else {
        (second.minus.clone(), first.plus)
    };
    let turning = second.turning_or_diverged || is_turning(&minus, &plus, inv_mass);
    Tree {
        minus,
        plus,
        sample,
        log_w,
        alpha_sum: first.alpha_sum + second.alpha_sum,
        n_leaves: first.n_leaves + second.n_leaves,
        turning_or_diverged: turning,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::{std_normal_density, FnDensity};
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    #[test]
    fn std_normal_moments() {
        let ld = std_normal_density(4);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let out = Nuts::default().sample(&ld, &[1.0, -1.0, 0.5, 0.0], 800, 3000, &mut rng);
        assert_eq!(out.thetas.len(), 3000);
        for i in 0..4 {
            let col: Vec<f64> = out.thetas.iter().map(|t| t[i]).collect();
            assert!(stats::mean(&col).abs() < 0.1, "dim {i}: {}", stats::mean(&col));
            assert!(
                (stats::variance(&col) - 1.0).abs() < 0.15,
                "dim {i}: {}",
                stats::variance(&col)
            );
        }
    }

    #[test]
    fn banana_like_target_mixes() {
        // Rosenbrock-ish curved target; NUTS should still recover the
        // marginal mean of x ≈ 0.
        let ld = FnDensity {
            dim: 2,
            f: |t: &[f64]| {
                -0.5 * (t[0] * t[0] + 4.0 * (t[1] - t[0] * t[0]) * (t[1] - t[0] * t[0]))
            },
            g: |t: &[f64]| {
                let d = t[1] - t[0] * t[0];
                (
                    -0.5 * (t[0] * t[0] + 4.0 * d * d),
                    vec![-t[0] + 8.0 * d * t[0], -4.0 * d],
                )
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let out = Nuts::default().sample(&ld, &[0.1, 0.1], 1000, 12000, &mut rng);
        let x: Vec<f64> = out.thetas.iter().map(|t| t[0]).collect();
        let y: Vec<f64> = out.thetas.iter().map(|t| t[1]).collect();
        assert!(stats::mean(&x).abs() < 0.25, "{}", stats::mean(&x));
        // E[y] = E[x²] = 1
        assert!((stats::mean(&y) - 1.0).abs() < 0.3, "{}", stats::mean(&y));
    }

    #[test]
    fn nuts_beats_fixed_hmc_on_stiff_target() {
        // anisotropic Gaussian: NUTS adapts; count grad evals are reported
        let ld = FnDensity {
            dim: 2,
            f: |t: &[f64]| -0.5 * (t[0] * t[0] / 25.0 + t[1] * t[1]),
            g: |t: &[f64]| {
                (
                    -0.5 * (t[0] * t[0] / 25.0 + t[1] * t[1]),
                    vec![-t[0] / 25.0, -t[1]],
                )
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let out = Nuts::default().sample(&ld, &[0.0, 0.0], 1000, 4000, &mut rng);
        let x: Vec<f64> = out.thetas.iter().map(|t| t[0]).collect();
        assert!((stats::variance(&x) - 25.0).abs() < 6.0, "{}", stats::variance(&x));
        assert!(out.stats.n_grad_evals > 0);
    }
}
