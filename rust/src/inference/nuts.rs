//! No-U-Turn Sampler (Hoffman & Gelman 2014), multinomial variant with
//! dual-averaging step-size adaptation — AdvancedHMC.jl's default, included
//! beyond the paper's static-HMC benchmarks as the "production" sampler.
//!
//! Tree states (θ, p, ∇) live in a [`StatePool`] free-list retained across
//! iterations: tree construction takes and returns pooled buffers instead
//! of allocating, so the steady-state NUTS loop matches static HMC's
//! allocation-free leapfrog (gradients already landed in place via
//! [`LogDensity::logp_grad_into`]; the pool removes the per-node
//! `Vec` churn that used to sit on top of it).

use rand_core::RngCore;

use crate::chain::SamplerStats;
use crate::gradient::LogDensity;
use crate::obs::metrics::{self, Counter};
use crate::util::rng::Rng;

use super::adapt::{DualAveraging, WelfordVar};
use super::RawDraws;

/// NUTS configuration.
#[derive(Clone, Debug)]
pub struct Nuts {
    pub step_size: f64,
    pub max_depth: usize,
    pub target_accept: f64,
    pub adapt_mass: bool,
    /// Probe a starting ε with the warmup adapter's doubling heuristic
    /// before dual averaging takes over. Default-on since the seeded
    /// statistical tests were re-baselined with the probe enabled.
    pub init_step_size: bool,
}

impl Default for Nuts {
    fn default() -> Self {
        Self {
            step_size: 0.1,
            max_depth: 10,
            target_accept: 0.8,
            adapt_mass: true,
            init_step_size: true,
        }
    }
}

/// One phase-space point with its cached gradient and log-density.
struct State {
    theta: Vec<f64>,
    p: Vec<f64>,
    grad: Vec<f64>,
    lp: f64,
}

impl State {
    fn zeros(dim: usize) -> Self {
        Self {
            theta: vec![0.0; dim],
            p: vec![0.0; dim],
            grad: vec![0.0; dim],
            lp: 0.0,
        }
    }

    fn copy_from(&mut self, src: &State) {
        self.theta.copy_from_slice(&src.theta);
        self.p.copy_from_slice(&src.p);
        self.grad.copy_from_slice(&src.grad);
        self.lp = src.lp;
    }
}

/// Free-list of tree [`State`]s. A NUTS iteration touches O(2^depth)
/// leapfrog states but only O(depth) are live at once; the pool retains
/// that working set across iterations, so after the first few iterations
/// tree construction allocates nothing (ROADMAP PR-3 follow-up: the NUTS
/// leapfrog now matches static HMC's allocation-free loop).
struct StatePool {
    free: Vec<State>,
    dim: usize,
    allocated: usize,
}

impl StatePool {
    fn new(dim: usize) -> Self {
        Self {
            free: Vec::new(),
            dim,
            allocated: 0,
        }
    }

    /// A state with unspecified contents (caller overwrites).
    fn take(&mut self) -> State {
        self.free.pop().unwrap_or_else(|| {
            self.allocated += 1;
            State::zeros(self.dim)
        })
    }

    /// A state holding a copy of `src`.
    fn take_copy(&mut self, src: &State) -> State {
        let mut s = self.take();
        s.copy_from(src);
        s
    }

    fn put(&mut self, s: State) {
        debug_assert_eq!(s.theta.len(), self.dim);
        self.free.push(s);
    }

    /// Total states ever created — bounded by tree geometry, not by
    /// iteration count.
    #[cfg(test)]
    fn allocated(&self) -> usize {
        self.allocated
    }

    /// States currently taken and not returned.
    fn outstanding(&self) -> usize {
        self.allocated - self.free.len()
    }
}

/// A (sub)tree: its two ends, a multinomial-sampled representative, and
/// merge bookkeeping. All three states are pool-owned and must be taken
/// from / returned to the iteration's [`StatePool`].
struct Tree {
    minus: State,
    plus: State,
    /// multinomial-sampled representative of this subtree
    sample: State,
    /// log of the subtree weight Σ exp(−H)
    log_w: f64,
    /// sum of min(1, exp(−ΔH)) over leaves (for adaptation)
    alpha_sum: f64,
    n_leaves: f64,
    /// stop extending this trajectory (U-turn *or* divergence)
    turning_or_diverged: bool,
    /// at least one leaf actually diverged (Stan's divergent-transition
    /// diagnostic — distinct from merely turning)
    diverged: bool,
}

impl Nuts {
    pub fn sample<R: RngCore>(
        &self,
        ld: &dyn LogDensity,
        theta0: &[f64],
        warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> RawDraws {
        let mut pool = StatePool::new(ld.dim());
        let out = self.sample_impl(ld, theta0, warmup, iters, rng, &mut pool);
        debug_assert_eq!(pool.outstanding(), 0, "tree states leaked from the pool");
        out
    }

    fn sample_impl<R: RngCore>(
        &self,
        ld: &dyn LogDensity,
        theta0: &[f64],
        warmup: usize,
        iters: usize,
        rng: &mut R,
        pool: &mut StatePool,
    ) -> RawDraws {
        let dim = ld.dim();
        let t_start = std::time::Instant::now();
        let mut probe_evals: u64 = 0;
        let mut eps = self.step_size;
        if self.init_step_size {
            let (probed, evals) =
                super::adapt::find_initial_step_size(ld, theta0, self.step_size, rng);
            eps = probed;
            probe_evals = evals;
        }
        let mut da = DualAveraging::new(eps, self.target_accept);
        let mut mass_est = WelfordVar::new(dim);
        let mut inv_mass: Vec<f64> = vec![1.0; dim];

        let mut grad0 = vec![0.0; dim];
        let lp0 = ld.logp_grad_into(theta0, &mut grad0);
        assert!(lp0.is_finite(), "NUTS initialized at zero-probability point");
        let mut n_grad: u64 = 1 + probe_evals;
        let mut current = State {
            theta: theta0.to_vec(),
            p: vec![0.0; dim],
            grad: grad0,
            lp: lp0,
        };

        let mut thetas = Vec::with_capacity(iters);
        let mut logps = Vec::with_capacity(iters);
        let mut divergences = 0usize;
        let mut max_treedepth_hits = 0usize;
        let mut accept_stat_sum = 0.0;
        let mut warmup_secs = 0.0;
        // per-iteration Hamiltonians (E-BFMI input); recorded only while
        // telemetry is live so the disabled path allocates nothing
        let mut energies: Vec<f64> = Vec::new();

        for it in 0..warmup + iters {
            for i in 0..dim {
                current.p[i] = rng.normal() / inv_mass[i].sqrt();
            }
            let h0 = hamiltonian(&current, &inv_mass);

            let mut minus = pool.take_copy(&current);
            let mut plus = pool.take_copy(&current);
            let mut sample = pool.take_copy(&current);
            // All weights are normalized relative to the initial energy:
            // the starting state has weight exp(h0 − h0) = 1.
            let mut log_w = 0.0;
            let mut depth = 0;
            let mut turning = false;
            let mut alpha_sum = 0.0;
            let mut n_leaves = 0.0;

            while depth < self.max_depth && !turning {
                let go_right = rng.bernoulli(0.5);
                let sub = if go_right {
                    build_tree(
                        ld, &plus, 1.0, depth, eps, h0, &inv_mass, rng, &mut n_grad, pool,
                    )
                } else {
                    build_tree(
                        ld, &minus, -1.0, depth, eps, h0, &inv_mass, rng, &mut n_grad, pool,
                    )
                };
                let Tree {
                    minus: sm,
                    plus: sp,
                    sample: ss,
                    log_w: sw,
                    alpha_sum: sa,
                    n_leaves: sn,
                    turning_or_diverged: st,
                    diverged: sdiv,
                } = sub;
                alpha_sum += sa;
                n_leaves += sn;
                if st {
                    // a divergence anywhere in the subtree marks the whole
                    // transition divergent (Stan's diagnostic semantics)
                    if sdiv {
                        divergences += 1;
                    }
                    pool.put(sm);
                    pool.put(sp);
                    pool.put(ss);
                    break;
                }
                // multinomial merge: accept subtree sample with prob w'/(w+w')
                let log_sum = log_add(log_w, sw);
                if rng.uniform_pos().ln() < sw - log_sum {
                    pool.put(std::mem::replace(&mut sample, ss));
                } else {
                    pool.put(ss);
                }
                log_w = log_sum;
                if go_right {
                    pool.put(std::mem::replace(&mut plus, sp));
                    pool.put(sm);
                } else {
                    pool.put(std::mem::replace(&mut minus, sm));
                    pool.put(sp);
                }
                turning = is_turning(&minus, &plus, &inv_mass);
                depth += 1;
            }

            // the loop ran out of depth while still willing to extend:
            // Stan's "maximum treedepth" saturation diagnostic (a subtree
            // break leaves depth strictly below the cap, so no false hit)
            let saturated = depth == self.max_depth && !turning;

            current.copy_from(&sample);
            pool.put(minus);
            pool.put(plus);
            pool.put(sample);
            let accept_stat = if n_leaves > 0.0 {
                alpha_sum / n_leaves
            } else {
                0.0
            };
            accept_stat_sum += accept_stat;

            if it < warmup {
                eps = da.update(accept_stat);
                if self.adapt_mass {
                    mass_est.push(&current.theta);
                    if mass_est.count() > 50 {
                        inv_mass = mass_est.variance();
                    }
                }
                if it + 1 == warmup {
                    eps = da.finalized();
                    warmup_secs = t_start.elapsed().as_secs_f64();
                }
            } else {
                if saturated {
                    max_treedepth_hits += 1;
                }
                if metrics::enabled() {
                    energies.push(h0);
                }
                thetas.push(current.theta.clone());
                logps.push(current.lp);
            }
        }

        // every grad eval beyond the init point and the ε probe is one
        // leapfrog step of some tree leaf
        metrics::add(Counter::LeapfrogSteps, n_grad - 1 - probe_evals);
        metrics::add(Counter::Divergences, divergences as u64);
        metrics::add(Counter::MaxTreedepthHits, max_treedepth_hits as u64);
        let wall_secs = t_start.elapsed().as_secs_f64();
        RawDraws {
            thetas,
            logps,
            stats: SamplerStats {
                accept_rate: accept_stat_sum / (warmup + iters) as f64,
                divergences,
                step_size: eps,
                n_grad_evals: n_grad,
                wall_secs,
                warmup_secs,
                sampling_secs: wall_secs - warmup_secs,
                max_treedepth_hits,
                energies,
                ..SamplerStats::default()
            },
        }
    }
}

fn hamiltonian(s: &State, inv_mass: &[f64]) -> f64 {
    let ke: f64 = 0.5
        * s.p
            .iter()
            .zip(inv_mass)
            .map(|(&pi, &im)| pi * pi * im)
            .sum::<f64>();
    -s.lp + ke
}

fn log_add(a: f64, b: f64) -> f64 {
    crate::util::math::log_add_exp(a, b)
}

/// One leapfrog step from `s` into the pooled state `out` — all buffer
/// writes in place, gradient via `logp_grad_into`.
fn leapfrog_into(
    ld: &dyn LogDensity,
    s: &State,
    dir: f64,
    eps: f64,
    inv_mass: &[f64],
    out: &mut State,
) {
    let dim = s.theta.len();
    let e = dir * eps;
    out.theta.copy_from_slice(&s.theta);
    out.p.copy_from_slice(&s.p);
    for i in 0..dim {
        out.p[i] += 0.5 * e * s.grad[i];
        out.theta[i] += e * out.p[i] * inv_mass[i];
    }
    out.lp = ld.logp_grad_into(&out.theta, &mut out.grad);
    for i in 0..dim {
        out.p[i] += 0.5 * e * out.grad[i];
    }
}

fn is_turning(minus: &State, plus: &State, inv_mass: &[f64]) -> bool {
    let mut dot_m = 0.0;
    let mut dot_p = 0.0;
    for i in 0..minus.theta.len() {
        let dq = plus.theta[i] - minus.theta[i];
        dot_m += dq * minus.p[i] * inv_mass[i];
        dot_p += dq * plus.p[i] * inv_mass[i];
    }
    dot_m < 0.0 || dot_p < 0.0
}

#[allow(clippy::too_many_arguments)]
fn build_tree<R: RngCore>(
    ld: &dyn LogDensity,
    start: &State,
    dir: f64,
    depth: usize,
    eps: f64,
    h0: f64,
    inv_mass: &[f64],
    rng: &mut R,
    n_grad: &mut u64,
    pool: &mut StatePool,
) -> Tree {
    if depth == 0 {
        let mut s = pool.take();
        leapfrog_into(ld, start, dir, eps, inv_mass, &mut s);
        *n_grad += 1;
        let h = hamiltonian(&s, inv_mass);
        let dh = h0 - h;
        let diverged = !dh.is_finite() || dh < -1000.0;
        let alpha = if dh.is_finite() { dh.exp().min(1.0) } else { 0.0 };
        let minus = pool.take_copy(&s);
        let plus = pool.take_copy(&s);
        return Tree {
            minus,
            plus,
            sample: s,
            log_w: if diverged { f64::NEG_INFINITY } else { dh },
            alpha_sum: alpha,
            n_leaves: 1.0,
            turning_or_diverged: diverged,
            diverged,
        };
    }
    let first = build_tree(ld, start, dir, depth - 1, eps, h0, inv_mass, rng, n_grad, pool);
    if first.turning_or_diverged {
        return first;
    }
    let second = {
        let cont = if dir > 0.0 { &first.plus } else { &first.minus };
        build_tree(ld, cont, dir, depth - 1, eps, h0, inv_mass, rng, n_grad, pool)
    };
    let Tree {
        minus: m1,
        plus: p1,
        sample: s1,
        log_w: w1,
        alpha_sum: a1,
        n_leaves: n1,
        ..
    } = first;
    let Tree {
        minus: m2,
        plus: p2,
        sample: s2,
        log_w: w2,
        alpha_sum: a2,
        n_leaves: n2,
        turning_or_diverged: t2,
        diverged: d2,
    } = second;
    let log_w = log_add(w1, w2);
    let pick_second = !t2 && rng.uniform_pos().ln() < w2 - log_w;
    let (sample, dead) = if pick_second { (s2, s1) } else { (s1, s2) };
    pool.put(dead);
    // of the four tree ends only the two outer ones survive the merge
    let (minus, plus) = if dir > 0.0 {
        pool.put(p1);
        pool.put(m2);
        (m1, p2)
    } else {
        pool.put(m1);
        pool.put(p2);
        (m2, p1)
    };
    let turning = t2 || is_turning(&minus, &plus, inv_mass);
    Tree {
        minus,
        plus,
        sample,
        log_w,
        alpha_sum: a1 + a2,
        n_leaves: n1 + n2,
        turning_or_diverged: turning,
        // `first` cannot carry a divergence here (it would have returned
        // early above), so the merged flag is second's alone
        diverged: d2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::{std_normal_density, FnDensity};
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    #[test]
    fn std_normal_moments() {
        let ld = std_normal_density(4);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let out = Nuts::default().sample(&ld, &[1.0, -1.0, 0.5, 0.0], 800, 3000, &mut rng);
        assert_eq!(out.thetas.len(), 3000);
        for i in 0..4 {
            let col: Vec<f64> = out.thetas.iter().map(|t| t[i]).collect();
            assert!(stats::mean(&col).abs() < 0.1, "dim {i}: {}", stats::mean(&col));
            assert!(
                (stats::variance(&col) - 1.0).abs() < 0.15,
                "dim {i}: {}",
                stats::variance(&col)
            );
        }
    }

    #[test]
    fn banana_like_target_mixes() {
        // Rosenbrock-ish curved target; NUTS should still recover the
        // marginal mean of x ≈ 0.
        let ld = FnDensity {
            dim: 2,
            f: |t: &[f64]| {
                -0.5 * (t[0] * t[0] + 4.0 * (t[1] - t[0] * t[0]) * (t[1] - t[0] * t[0]))
            },
            g: |t: &[f64]| {
                let d = t[1] - t[0] * t[0];
                (
                    -0.5 * (t[0] * t[0] + 4.0 * d * d),
                    vec![-t[0] + 8.0 * d * t[0], -4.0 * d],
                )
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(12);
        let out = Nuts::default().sample(&ld, &[0.1, 0.1], 1000, 12000, &mut rng);
        let x: Vec<f64> = out.thetas.iter().map(|t| t[0]).collect();
        let y: Vec<f64> = out.thetas.iter().map(|t| t[1]).collect();
        assert!(stats::mean(&x).abs() < 0.25, "{}", stats::mean(&x));
        // E[y] = E[x²] = 1
        assert!((stats::mean(&y) - 1.0).abs() < 0.3, "{}", stats::mean(&y));
    }

    #[test]
    fn nuts_beats_fixed_hmc_on_stiff_target() {
        // anisotropic Gaussian: NUTS adapts; count grad evals are reported
        let ld = FnDensity {
            dim: 2,
            f: |t: &[f64]| -0.5 * (t[0] * t[0] / 25.0 + t[1] * t[1]),
            g: |t: &[f64]| {
                (
                    -0.5 * (t[0] * t[0] / 25.0 + t[1] * t[1]),
                    vec![-t[0] / 25.0, -t[1]],
                )
            },
        };
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let out = Nuts::default().sample(&ld, &[0.0, 0.0], 1000, 4000, &mut rng);
        let x: Vec<f64> = out.thetas.iter().map(|t| t[0]).collect();
        assert!((stats::variance(&x) - 25.0).abs() < 6.0, "{}", stats::variance(&x));
        assert!(out.stats.n_grad_evals > 0);
    }

    #[test]
    fn tree_state_pool_is_bounded_and_recycled() {
        // The pool's total allocation is a function of tree depth, not of
        // iteration count: after warm-up every take() hits the free list.
        let ld = std_normal_density(3);
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let nuts = Nuts::default();
        let mut pool = StatePool::new(3);
        let out = nuts.sample_impl(&ld, &[0.1, 0.2, -0.3], 200, 800, &mut rng, &mut pool);
        assert_eq!(out.thetas.len(), 800);
        assert!(
            pool.allocated() <= 8 * (nuts.max_depth + 2),
            "pool allocated {} states over 1000 iterations",
            pool.allocated()
        );
        // every taken state came back
        assert_eq!(pool.outstanding(), 0);
    }

    #[test]
    fn state_pool_reuses_buffers() {
        let mut pool = StatePool::new(2);
        let a = pool.take();
        let ptr = a.theta.as_ptr();
        pool.put(a);
        let b = pool.take();
        assert_eq!(b.theta.as_ptr(), ptr, "free-listed state must be reused");
        assert_eq!(pool.allocated(), 1);
        let src = State {
            theta: vec![1.0, 2.0],
            p: vec![3.0, 4.0],
            grad: vec![5.0, 6.0],
            lp: -7.0,
        };
        let mut c = pool.take_copy(&src);
        assert_eq!(c.theta, vec![1.0, 2.0]);
        assert_eq!(c.lp, -7.0);
        c.copy_from(&b);
        pool.put(b);
        pool.put(c);
        assert_eq!(pool.outstanding(), 0);
    }
}
