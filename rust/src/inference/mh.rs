//! Random-walk Metropolis–Hastings over unconstrained coordinates.
//! Gradient-free: exercises the pure log-density path (and is the
//! within-block sampler for Gibbs).

use rand_core::RngCore;

use crate::chain::SamplerStats;
use crate::gradient::LogDensity;
use crate::util::rng::Rng;

use super::RawDraws;

/// Random-walk MH with isotropic Gaussian proposals.
#[derive(Clone, Debug)]
pub struct RwMh {
    /// Proposal standard deviation.
    pub scale: f64,
    /// Adapt the scale toward 23.4% acceptance during warmup.
    pub adapt_scale: bool,
}

impl Default for RwMh {
    fn default() -> Self {
        Self {
            scale: 0.5,
            adapt_scale: true,
        }
    }
}

impl RwMh {
    pub fn new(scale: f64) -> Self {
        Self {
            scale,
            adapt_scale: true,
        }
    }

    pub fn sample<R: RngCore>(
        &self,
        ld: &dyn LogDensity,
        theta0: &[f64],
        warmup: usize,
        iters: usize,
        rng: &mut R,
    ) -> RawDraws {
        let dim = ld.dim();
        let t_start = std::time::Instant::now();
        let mut theta = theta0.to_vec();
        let mut lp = ld.logp(&theta);
        assert!(lp.is_finite(), "MH initialized at zero-probability point");

        let mut scale = self.scale;
        let mut thetas = Vec::with_capacity(iters);
        let mut logps = Vec::with_capacity(iters);
        let mut accepts = 0usize;
        let mut warmup_secs = 0.0;
        let mut prop = vec![0.0; dim];

        for it in 0..warmup + iters {
            for i in 0..dim {
                prop[i] = theta[i] + scale * rng.normal();
            }
            let lp_prop = ld.logp(&prop);
            let accepted = lp_prop.is_finite() && rng.uniform_pos().ln() < lp_prop - lp;
            if accepted {
                theta.copy_from_slice(&prop);
                lp = lp_prop;
            }
            if it < warmup {
                if self.adapt_scale {
                    // Robbins–Monro toward 0.234 acceptance
                    let acc = if accepted { 1.0 } else { 0.0 };
                    let eta = (it as f64 + 10.0).powf(-0.6);
                    scale = (scale.ln() + eta * (acc - 0.234)).exp();
                }
                if it + 1 == warmup {
                    warmup_secs = t_start.elapsed().as_secs_f64();
                }
            } else {
                if accepted {
                    accepts += 1;
                }
                thetas.push(theta.clone());
                logps.push(lp);
            }
        }

        let wall_secs = t_start.elapsed().as_secs_f64();
        RawDraws {
            thetas,
            logps,
            stats: SamplerStats {
                accept_rate: if iters > 0 {
                    accepts as f64 / iters as f64
                } else {
                    0.0
                },
                divergences: 0,
                step_size: scale,
                n_grad_evals: 0,
                wall_secs,
                warmup_secs,
                sampling_secs: wall_secs - warmup_secs,
                ..SamplerStats::default()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gradient::std_normal_density;
    use crate::util::rng::Xoshiro256pp;
    use crate::util::stats;

    #[test]
    fn std_normal_moments() {
        let ld = std_normal_density(2);
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let out = RwMh::default().sample(&ld, &[3.0, -3.0], 2000, 30_000, &mut rng);
        for i in 0..2 {
            let col: Vec<f64> = out.thetas.iter().map(|t| t[i]).collect();
            assert!(stats::mean(&col).abs() < 0.1);
            assert!((stats::variance(&col) - 1.0).abs() < 0.15);
        }
    }

    #[test]
    fn adaptation_reaches_reasonable_acceptance() {
        let ld = std_normal_density(5);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let out = RwMh::new(10.0).sample(&ld, &[0.0; 5], 3000, 5000, &mut rng);
        assert!(
            out.stats.accept_rate > 0.1 && out.stats.accept_rate < 0.5,
            "acceptance {}",
            out.stats.accept_rate
        );
    }
}
