//! Per-tilde-site profiling — the contextual-dispatch showcase.
//!
//! Running a model under [`Context::Profile`] makes every flat executor
//! (typed, untyped, typed-fused, untyped-fused) record one row per tilde
//! statement into a thread-local collector: wall-clock nanoseconds, the
//! site's own log-density contribution, and whether the site triggered a
//! −∞ rejection. Assume sites are keyed by their `VarName`; observe sites
//! by visit index (`obs[k]`). Under every other context the executors'
//! instrumentation is a single enum compare — the hot paths never reach
//! the collector.
//!
//! [`profile_model`] is the canonical driver: one instrumented evaluation
//! through each of the four flat executor monomorphizations, rows tagged
//! with the path that produced them.

use std::cell::{Cell, RefCell};
use std::time::Instant;

use crate::context::Context;
use crate::varname::VarName;

/// One profiled tilde site (aggregated over repeated visits).
#[derive(Clone, Debug)]
pub struct SiteProfile {
    /// Executor path that recorded the row (`typed`, `untyped`,
    /// `typed+fused`, `untyped+fused`).
    pub path: &'static str,
    /// Site key: the assume's `VarName`, or `obs[k]` by visit index.
    pub site: String,
    /// Times the site was visited.
    pub calls: u64,
    /// Total wall-clock nanoseconds across visits.
    pub nanos: u64,
    /// Total log-density contribution across visits.
    pub logp: f64,
    /// Visits that left the run rejected (−∞ attribution).
    pub rejections: u64,
}

/// Open timing guard for one tilde statement; `None` outside
/// [`Context::Profile`] so the instrumentation costs one compare.
pub struct SiteTimer {
    t0: Instant,
}

thread_local! {
    static ROWS: RefCell<Vec<SiteProfile>> = const { RefCell::new(Vec::new()) };
    static PATH: Cell<&'static str> = const { Cell::new("") };
    static OBS_IDX: Cell<usize> = const { Cell::new(0) };
}

/// Start a profiling pass: tag subsequent rows with `path` and restart
/// the observe-site index.
pub fn begin_pass(path: &'static str) {
    PATH.with(|p| p.set(path));
    OBS_IDX.with(|i| i.set(0));
}

/// Start timing one tilde statement. Returns `None` (and does nothing)
/// unless the evaluation runs under [`Context::Profile`] with the
/// `telemetry` feature compiled in.
#[inline]
pub fn begin(ctx: Context) -> Option<SiteTimer> {
    if cfg!(feature = "telemetry") && ctx == Context::Profile {
        Some(SiteTimer { t0: Instant::now() })
    } else {
        None
    }
}

/// Close an assume-site timing, keyed by the variable name.
#[inline]
pub fn end_assume(t: Option<SiteTimer>, vn: &VarName, logp: f64, rejected: bool) {
    if let Some(t) = t {
        record(vn.to_string(), t.t0.elapsed().as_nanos() as u64, logp, rejected);
    }
}

/// Close an observe-site timing, keyed by visit index.
#[inline]
pub fn end_observe(t: Option<SiteTimer>, logp: f64, rejected: bool) {
    if let Some(t) = t {
        let idx = OBS_IDX.with(|i| {
            let k = i.get();
            i.set(k + 1);
            k
        });
        record(format!("obs[{idx}]"), t.t0.elapsed().as_nanos() as u64, logp, rejected);
    }
}

fn record(site: String, nanos: u64, logp: f64, rejected: bool) {
    let path = PATH.with(|p| p.get());
    ROWS.with(|rows| {
        let mut rows = rows.borrow_mut();
        if let Some(r) = rows.iter_mut().find(|r| r.path == path && r.site == site) {
            r.calls += 1;
            r.nanos += nanos;
            r.logp += logp;
            r.rejections += u64::from(rejected);
        } else {
            rows.push(SiteProfile {
                path,
                site,
                calls: 1,
                nanos,
                logp,
                rejections: u64::from(rejected),
            });
        }
    });
}

/// Drain the calling thread's collected rows.
pub fn take_rows() -> Vec<SiteProfile> {
    ROWS.with(|rows| std::mem::take(&mut *rows.borrow_mut()))
}

/// One instrumented evaluation through each of the four flat executor
/// monomorphizations at the same unconstrained point: typed and untyped
/// plain log-density, typed and untyped arena-fused gradient. The untyped
/// passes rebuild a boxed trace from the model's prior (`seed`) purely for
/// its structure; they are skipped if its layout disagrees with `theta`
/// (dynamic structure change since specialization).
pub fn profile_model(
    model: &dyn crate::model::Model,
    tvi: &crate::varinfo::TypedVarInfo,
    theta: &[f64],
    seed: u64,
) -> Vec<SiteProfile> {
    let _ = take_rows(); // isolate from any prior collection on this thread
    let mut grad = vec![0.0; theta.len()];

    begin_pass("typed");
    let _ = crate::model::typed_logp(model, tvi, theta, Context::Profile);
    begin_pass("typed+fused");
    let _ = crate::model::typed_grad_fused_into(model, tvi, theta, Context::Profile, &mut grad);

    let mut rng = crate::util::rng::Xoshiro256pp::seed_from_u64(seed);
    let uvi = crate::model::init_trace(model, &mut rng);
    if uvi.num_unconstrained() == theta.len() {
        begin_pass("untyped");
        let _ = crate::model::untyped_logp(model, &uvi, theta, Context::Profile);
        begin_pass("untyped+fused");
        let _ =
            crate::model::untyped_grad_fused_into(model, &uvi, theta, Context::Profile, &mut grad);
    }
    take_rows()
}

/// Render profile rows as an aligned human-readable table.
pub fn render_profile(rows: &[SiteProfile]) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<14} {:<16} {:>6} {:>12} {:>12} {:>6}",
        "path", "site", "calls", "ns total", "logp", "rej"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<14} {:<16} {:>6} {:>12} {:>12.4} {:>6}",
            r.path, r.site, r.calls, r.nanos, r.logp, r.rejections
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn non_profile_contexts_record_nothing() {
        let _ = take_rows();
        assert!(begin(Context::Default).is_none());
        assert!(begin(Context::Likelihood).is_none());
        end_assume(None, &crate::varname::VarName::new("x"), -1.0, false);
        end_observe(None, -2.0, false);
        assert!(take_rows().is_empty());
    }

    #[test]
    fn profile_rows_aggregate_by_site() {
        let _ = take_rows();
        begin_pass("typed");
        let vn = crate::varname::VarName::new("mu");
        end_assume(begin(Context::Profile), &vn, -0.5, false);
        end_assume(begin(Context::Profile), &vn, -0.25, true);
        end_observe(begin(Context::Profile), -2.0, false);
        let rows = take_rows();
        assert_eq!(rows.len(), 2);
        let mu = rows.iter().find(|r| r.site == "mu").unwrap();
        assert_eq!(mu.calls, 2);
        assert_eq!(mu.rejections, 1);
        assert!((mu.logp + 0.75).abs() < 1e-12);
        assert!(rows.iter().any(|r| r.site == "obs[0]"));
        // drained
        assert!(take_rows().is_empty());
    }
}
