//! Stan-parity post-run diagnostics: structured warnings, a human report,
//! and the machine-readable `METRICS.json` payload.
//!
//! [`RunReport::from_chains`] folds a [`MultiChain`] (plus optional
//! per-site profile rows) into one structure; [`RunReport::render_human`]
//! and [`RunReport::to_json`] render that same structure, so the human and
//! machine outputs can never drift apart.

use std::fmt::Write as _;

use crate::chain::MultiChain;

use super::metrics::{Counter, MetricsSnapshot, ALL_COUNTERS};
use super::profile::SiteProfile;

/// E-BFMI warning threshold (Betancourt 2016; Stan warns below 0.3).
pub const EBFMI_WARN: f64 = 0.3;
/// Bulk-ESS warning threshold (Stan's rule of thumb: 100 per chain set).
pub const ESS_WARN: f64 = 100.0;
/// Split-R̂ warning threshold (Vehtari et al. 2021).
pub const RHAT_WARN: f64 = 1.01;

/// Energy–Bayesian-fraction-of-missing-information of one chain's
/// per-iteration Hamiltonian series: Σ(E_i − E_{i−1})² / Σ(E_i − Ē)².
/// `NaN` when fewer than two energies were recorded (non-HMC samplers,
/// or telemetry disabled).
pub fn ebfmi(energies: &[f64]) -> f64 {
    if energies.len() < 2 {
        return f64::NAN;
    }
    let n = energies.len() as f64;
    let mean = energies.iter().sum::<f64>() / n;
    let num: f64 = energies.windows(2).map(|w| (w[1] - w[0]).powi(2)).sum();
    let den: f64 = energies.iter().map(|e| (e - mean).powi(2)).sum();
    if den == 0.0 {
        f64::NAN
    } else {
        num / den
    }
}

/// One post-run diagnostic warning.
#[derive(Clone, Debug)]
pub enum Warning {
    /// Post-warmup divergent transitions (chain-indexed location).
    Divergences { chain: usize, count: usize },
    /// NUTS trajectories stopped by the maximum tree depth.
    TreedepthSaturation { chain: usize, count: usize },
    /// E-BFMI below [`EBFMI_WARN`]: heavy-tailed energy marginal.
    LowEbfmi { chain: usize, ebfmi: f64 },
    /// Effective sample size below [`ESS_WARN`].
    LowEss { param: String, ess: f64 },
    /// Split-R̂ above [`RHAT_WARN`].
    HighRhat { param: String, rhat: f64 },
    /// The ADVI η ladder found no finite candidate.
    EtaSearchFailed { chain: usize },
    /// A static-analysis lint finding (`dppl lint` pedantic pass) attached
    /// to the run; `code` is the lint's stable key (e.g. `centered-funnel`).
    Lint {
        code: String,
        site: String,
        message: String,
    },
}

impl Warning {
    /// Stable machine key for the warning class.
    pub fn kind(&self) -> &'static str {
        match self {
            Warning::Divergences { .. } => "divergences",
            Warning::TreedepthSaturation { .. } => "max_treedepth",
            Warning::LowEbfmi { .. } => "low_ebfmi",
            Warning::LowEss { .. } => "low_ess",
            Warning::HighRhat { .. } => "high_rhat",
            Warning::EtaSearchFailed { .. } => "eta_search_failed",
            Warning::Lint { .. } => "lint",
        }
    }

    /// Stan-flavored human message.
    pub fn message(&self) -> String {
        match self {
            Warning::Divergences { chain, count } => format!(
                "chain {chain}: {count} post-warmup divergent transition(s) — \
                 the posterior may have high curvature; consider a smaller \
                 step size or a reparameterization"
            ),
            Warning::TreedepthSaturation { chain, count } => format!(
                "chain {chain}: {count} transition(s) hit the maximum tree \
                 depth — increase max_depth or reparameterize"
            ),
            Warning::LowEbfmi { chain, ebfmi } => format!(
                "chain {chain}: E-BFMI = {ebfmi:.3} < {EBFMI_WARN} — momentum \
                 resampling is exploring the energy marginal poorly"
            ),
            Warning::LowEss { param, ess } => format!(
                "parameter {param}: ESS = {ess:.1} < {ESS_WARN} — estimates \
                 may be unreliable; run longer chains"
            ),
            Warning::HighRhat { param, rhat } => format!(
                "parameter {param}: split-R\u{302} = {rhat:.3} > {RHAT_WARN} — \
                 chains have not mixed"
            ),
            Warning::EtaSearchFailed { chain } => format!(
                "chain {chain}: ADVI η ladder search failed — fit used the \
                 smallest candidate step size and may not have converged"
            ),
            Warning::Lint {
                code,
                site,
                message,
            } => format!("[{code}] {site}: {message}"),
        }
    }
}

/// Per-chain sampler diagnostics.
#[derive(Clone, Debug)]
pub struct ChainReport {
    pub chain: usize,
    pub accept_rate: f64,
    pub step_size: f64,
    pub divergences: usize,
    pub max_treedepth_hits: usize,
    pub n_grad_evals: u64,
    pub wall_secs: f64,
    pub warmup_secs: f64,
    pub sampling_secs: f64,
    /// `NaN` when the sampler recorded no energies.
    pub ebfmi: f64,
    pub eta_search_failed: bool,
    pub metrics: MetricsSnapshot,
}

/// Per-parameter convergence diagnostics.
#[derive(Clone, Debug)]
pub struct ParamDiag {
    pub name: String,
    pub rhat: f64,
    /// Total ESS summed over chains.
    pub ess: f64,
}

/// The complete post-run report: one structure behind both the human
/// rendering and `METRICS.json`.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub model: String,
    pub sampler: String,
    pub chains: Vec<ChainReport>,
    pub params: Vec<ParamDiag>,
    pub log_evidence: Option<f64>,
    pub warnings: Vec<Warning>,
    pub profile: Vec<SiteProfile>,
}

impl RunReport {
    /// Build the report from sampled chains (+ optional profile rows).
    pub fn from_chains(
        model: &str,
        sampler: &str,
        mc: &MultiChain,
        profile: Vec<SiteProfile>,
    ) -> Self {
        let mut chains = Vec::with_capacity(mc.chains.len());
        let mut warnings = Vec::new();
        for (i, c) in mc.chains.iter().enumerate() {
            let s = &c.stats;
            let e = ebfmi(&s.energies);
            if s.divergences > 0 {
                warnings.push(Warning::Divergences {
                    chain: i,
                    count: s.divergences,
                });
            }
            if s.max_treedepth_hits > 0 {
                warnings.push(Warning::TreedepthSaturation {
                    chain: i,
                    count: s.max_treedepth_hits,
                });
            }
            if e.is_finite() && e < EBFMI_WARN {
                warnings.push(Warning::LowEbfmi { chain: i, ebfmi: e });
            }
            if s.eta_search_failed {
                warnings.push(Warning::EtaSearchFailed { chain: i });
            }
            chains.push(ChainReport {
                chain: i,
                accept_rate: s.accept_rate,
                step_size: s.step_size,
                divergences: s.divergences,
                max_treedepth_hits: s.max_treedepth_hits,
                n_grad_evals: s.n_grad_evals,
                wall_secs: s.wall_secs,
                warmup_secs: s.warmup_secs,
                sampling_secs: s.sampling_secs,
                ebfmi: e,
                eta_search_failed: s.eta_search_failed,
                metrics: s.metrics.clone(),
            });
        }

        let mut params = Vec::new();
        for name in mc.chains[0].names() {
            let rhat = mc.rhat(name).unwrap_or(f64::NAN);
            let ess = mc.ess(name).unwrap_or(f64::NAN);
            if rhat.is_finite() && rhat > RHAT_WARN {
                warnings.push(Warning::HighRhat {
                    param: name.clone(),
                    rhat,
                });
            }
            if ess.is_finite() && ess < ESS_WARN {
                warnings.push(Warning::LowEss {
                    param: name.clone(),
                    ess,
                });
            }
            params.push(ParamDiag {
                name: name.clone(),
                rhat,
                ess,
            });
        }

        Self {
            model: model.to_string(),
            sampler: sampler.to_string(),
            chains,
            params,
            log_evidence: mc.log_evidence(),
            warnings,
            profile,
        }
    }

    /// Human rendering: summary table, per-chain line, diagnostics,
    /// warnings — the coordinator's default output.
    pub fn render_human(&self, mc: &MultiChain) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", mc.chains[0].summary());
        let _ = writeln!(
            out,
            "model: {}  sampler: {}  chains: {}",
            self.model,
            self.sampler,
            self.chains.len()
        );
        for c in &self.chains {
            let _ = writeln!(
                out,
                "  chain {}: accept={:.2} divergences={} treedepth_hits={} grad_evals={} \
                 wall={:.2}s (warmup {:.2}s + sampling {:.2}s){}",
                c.chain,
                c.accept_rate,
                c.divergences,
                c.max_treedepth_hits,
                c.n_grad_evals,
                c.wall_secs,
                c.warmup_secs,
                c.sampling_secs,
                if c.ebfmi.is_finite() {
                    format!(" ebfmi={:.2}", c.ebfmi)
                } else {
                    String::new()
                },
            );
            if !c.metrics.is_empty() {
                let m = &c.metrics;
                let _ = writeln!(
                    out,
                    "    metrics: logp_evals={} grad_evals={} leapfrog_steps={} \
                     arena_nodes/eval={:.1} rejected_evals={}",
                    m.get(Counter::LogpEvals),
                    m.get(Counter::GradEvals),
                    m.get(Counter::LeapfrogSteps),
                    if m.arena_nodes_per_eval().is_finite() {
                        m.arena_nodes_per_eval()
                    } else {
                        0.0
                    },
                    m.get(Counter::RejectedEvals),
                );
                // executor family actually serving this chain's gradients
                let family = if m.get(Counter::StaticPromotions) > 0 {
                    "compiled-static"
                } else if m.get(Counter::ArenaEvals) > 0 {
                    "typed-fused"
                } else {
                    "dynamic"
                };
                let _ = writeln!(
                    out,
                    "    executor: {family} (promotions={} demotions={} plate_kernel_calls={})",
                    m.get(Counter::StaticPromotions),
                    m.get(Counter::StaticDemotions),
                    m.get(Counter::PlateKernelCalls),
                );
            }
        }
        for p in self.params.iter().take(8) {
            if p.rhat.is_finite() {
                let _ = writeln!(out, "  R\u{302}({}) = {:.4}  ESS = {:.1}", p.name, p.rhat, p.ess);
            }
        }
        if let Some(lz) = self.log_evidence {
            let _ = writeln!(out, "  log Z\u{302} = {lz:.4}");
        }
        if !self.profile.is_empty() {
            let _ = writeln!(out, "\nper-site profile:");
            out.push_str(&super::profile::render_profile(&self.profile));
        }
        if self.warnings.is_empty() {
            let _ = writeln!(out, "\nno diagnostic warnings.");
        } else {
            let _ = writeln!(out, "\nwarnings:");
            for w in &self.warnings {
                let _ = writeln!(out, "  [{}] {}", w.kind(), w.message());
            }
        }
        out
    }

    /// The `METRICS.json` payload (hand-rolled — no serde in the offline
    /// dependency set; non-finite numbers map to `null`).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\n  \"model\": \"{}\",\n  \"sampler\": \"{}\",\n  \"n_chains\": {},\n  \"chains\": [\n",
            jstr(&self.model),
            jstr(&self.sampler),
            self.chains.len()
        );
        for (i, c) in self.chains.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"chain\": {}, \"accept_rate\": {}, \"step_size\": {}, \
                 \"divergences\": {}, \"max_treedepth_hits\": {}, \"n_grad_evals\": {}, \
                 \"wall_secs\": {}, \"warmup_secs\": {}, \"sampling_secs\": {}, \
                 \"ebfmi\": {}, \"eta_search_failed\": {}, \"metrics\": {{",
                c.chain,
                jnum(c.accept_rate),
                jnum(c.step_size),
                c.divergences,
                c.max_treedepth_hits,
                c.n_grad_evals,
                jnum(c.wall_secs),
                jnum(c.warmup_secs),
                jnum(c.sampling_secs),
                jnum(c.ebfmi),
                c.eta_search_failed,
            );
            for (j, counter) in ALL_COUNTERS.iter().enumerate() {
                if j > 0 {
                    out.push_str(", ");
                }
                let _ = write!(out, "\"{}\": {}", counter.key(), c.metrics.get(*counter));
            }
            let _ = write!(
                out,
                ", \"arena_nodes_per_eval\": {}}}}}",
                jnum(c.metrics.arena_nodes_per_eval())
            );
            out.push_str(if i + 1 < self.chains.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ],\n  \"params\": [\n");
        for (i, p) in self.params.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"name\": \"{}\", \"rhat\": {}, \"ess\": {}}}",
                jstr(&p.name),
                jnum(p.rhat),
                jnum(p.ess)
            );
            out.push_str(if i + 1 < self.params.len() { ",\n" } else { "\n" });
        }
        let _ = write!(
            out,
            "  ],\n  \"log_evidence\": {},\n  \"profile\": [\n",
            self.log_evidence.map_or("null".to_string(), jnum)
        );
        for (i, r) in self.profile.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"path\": \"{}\", \"site\": \"{}\", \"calls\": {}, \
                 \"nanos\": {}, \"logp\": {}, \"rejections\": {}}}",
                jstr(r.path),
                jstr(&r.site),
                r.calls,
                r.nanos,
                jnum(r.logp),
                r.rejections
            );
            out.push_str(if i + 1 < self.profile.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ],\n  \"warnings\": [\n");
        for (i, w) in self.warnings.iter().enumerate() {
            let _ = write!(
                out,
                "    {{\"kind\": \"{}\", \"message\": \"{}\"}}",
                w.kind(),
                jstr(&w.message())
            );
            out.push_str(if i + 1 < self.warnings.len() { ",\n" } else { "\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }
}

fn jnum(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Minimal JSON string escape (quotes, backslashes, newlines).
fn jstr(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Chain;

    fn chain_with(f: impl Fn(&mut Chain)) -> Chain {
        let mut c = Chain::new(vec!["x".into()]);
        let mut v = 0.13;
        for _ in 0..400 {
            // a deterministic low-autocorrelation series: ESS is healthy
            v = (v * 997.0).sin();
            c.push(vec![v], -v * v);
        }
        f(&mut c);
        c
    }

    #[test]
    fn ebfmi_matches_definition() {
        assert!(ebfmi(&[]).is_nan());
        assert!(ebfmi(&[1.0]).is_nan());
        // constant energies: zero denominator
        assert!(ebfmi(&[2.0, 2.0, 2.0]).is_nan());
        let e = [1.0, 2.0, 4.0];
        // mean 7/3; num = 1 + 4 = 5; den = (−4/3)² + (−1/3)² + (5/3)²
        let den = (16.0 + 1.0 + 25.0) / 9.0;
        assert!((ebfmi(&e) - 5.0 / den).abs() < 1e-12);
    }

    #[test]
    fn warnings_fire_on_bad_chains() {
        let a = chain_with(|c| {
            c.stats.divergences = 3;
            c.stats.max_treedepth_hits = 2;
            c.stats.eta_search_failed = true;
            // oscillating energy: high E-BFMI (no warning); low E-BFMI
            // needs a slowly-drifting series instead
            c.stats.energies = (0..100).map(|i| (i as f64) * 0.1).collect();
        });
        let b = chain_with(|_| {});
        let mc = MultiChain::new(vec![a, b]);
        let rep = RunReport::from_chains("demo", "nuts", &mc, Vec::new());
        let kinds: Vec<&str> = rep.warnings.iter().map(|w| w.kind()).collect();
        assert!(kinds.contains(&"divergences"), "{kinds:?}");
        assert!(kinds.contains(&"max_treedepth"), "{kinds:?}");
        assert!(kinds.contains(&"eta_search_failed"), "{kinds:?}");
        // the linear-drift energy series has tiny squared jumps relative
        // to its variance → E-BFMI far below 0.3
        assert!(kinds.contains(&"low_ebfmi"), "{kinds:?}");
        assert!(rep.chains[0].ebfmi < EBFMI_WARN);
        assert!(rep.chains[1].ebfmi.is_nan());
    }

    #[test]
    fn clean_chains_report_no_warnings() {
        let mc = MultiChain::new(vec![chain_with(|_| {}), chain_with(|_| {})]);
        let rep = RunReport::from_chains("demo", "hmc", &mc, Vec::new());
        assert!(rep.warnings.is_empty(), "{:?}", rep.warnings);
        let human = rep.render_human(&mc);
        assert!(human.contains("no diagnostic warnings"));
        assert!(human.contains("warmup"));
    }

    #[test]
    #[cfg(feature = "telemetry")]
    fn human_report_names_the_executor_family() {
        use super::super::metrics;
        let a = chain_with(|c| {
            let _ = metrics::take_local();
            metrics::set_enabled(true);
            metrics::inc(Counter::GradEvals);
            metrics::inc(Counter::ArenaEvals);
            metrics::inc(Counter::StaticPromotions);
            metrics::inc(Counter::StaticDemotions);
            metrics::add(Counter::PlateKernelCalls, 7);
            c.stats.metrics = metrics::take_local();
        });
        let b = chain_with(|c| {
            let _ = metrics::take_local();
            metrics::set_enabled(true);
            metrics::inc(Counter::GradEvals);
            metrics::inc(Counter::ArenaEvals);
            c.stats.metrics = metrics::take_local();
        });
        let mc = MultiChain::new(vec![a, b]);
        let rep = RunReport::from_chains("demo", "nuts", &mc, Vec::new());
        let human = rep.render_human(&mc);
        assert!(human.contains("executor: compiled-static"), "{human}");
        assert!(human.contains("executor: typed-fused"), "{human}");
        assert!(human.contains("plate_kernel_calls=7"), "{human}");
        // the JSON side carries the raw counters
        let json = rep.to_json();
        for key in [
            "\"static_promotions\": 1",
            "\"static_demotions\": 1",
            "\"plate_kernel_calls\": 7",
        ] {
            assert!(json.contains(key), "missing {key} in:\n{json}");
        }
    }

    #[test]
    fn json_payload_is_balanced_and_keyed() {
        let a = chain_with(|c| {
            c.stats.divergences = 1;
            c.stats.warmup_secs = 0.5;
            c.stats.sampling_secs = 1.5;
        });
        let mc = MultiChain::new(vec![a]);
        let profile = vec![SiteProfile {
            path: "typed",
            site: "mu".into(),
            calls: 1,
            nanos: 42,
            logp: -0.5,
            rejections: 0,
        }];
        let rep = RunReport::from_chains("demo", "nuts", &mc, profile);
        let json = rep.to_json();
        for key in [
            "\"divergences\"",
            "\"grad_evals\"",
            "\"typed_promotions\"",
            "\"arena_nodes\"",
            "\"arena_nodes_per_eval\"",
            "\"warmup_secs\"",
            "\"sampling_secs\"",
            "\"ebfmi\"",
            "\"profile\"",
            "\"site\": \"mu\"",
            "\"kind\": \"divergences\"",
        ] {
            assert!(json.contains(key), "missing {key} in:\n{json}");
        }
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains(",\n  ]"));
        assert!(!json.contains("NaN"));
    }
}
