//! Thread-local sharded metrics registry.
//!
//! Counters live in a fixed-size per-thread array indexed by the
//! [`Counter`] enum — no hashing, no locking, no allocation on the hot
//! path. Instrumented code calls [`inc`]/[`add`]; the chain drivers drain
//! the calling thread's shard with [`take_local`] at chain join and attach
//! the snapshot to `SamplerStats.metrics`, so per-chain counts survive the
//! thread-pool boundary without any cross-thread synchronization.
//!
//! Cost model: with the `telemetry` cargo feature off (`cfg!` folds the
//! guard to a constant) every call compiles to nothing; with the feature
//! on but the runtime guard off ([`set_enabled`]`(false)`) a call is one
//! predictable thread-local bool read. Either way nothing here touches an
//! RNG stream or allocates, so seeded draws are bit-identical with
//! telemetry on, off, or compiled out.
//!
//! Attribution caveat: shards are per thread. Work an algorithm fans out
//! to *inner* pool threads (e.g. SMC particle propagation with
//! `threads > 1`) lands in those threads' shards and is not merged into
//! the driving chain's snapshot.

use std::cell::{Cell, RefCell};

/// The fixed metric catalog. Every counter is a monotone `u64` within one
/// chain run; derived rates (e.g. arena nodes **per** eval) are computed
/// at reporting time from the raw sums.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// Plain log-density evaluations through the model entry points.
    LogpEvals,
    /// Gradient evaluations (any engine) through the model entry points.
    GradEvals,
    /// Evaluations rejected early (−∞ / non-finite log-density).
    RejectedEvals,
    /// Arena-fused backward passes.
    ArenaEvals,
    /// Arena tape nodes summed over fused backward passes.
    ArenaNodes,
    /// Analytic-adjoint seeds summed over fused backward passes.
    ArenaSeeds,
    /// Leapfrog steps taken by HMC/NUTS trajectories.
    LeapfrogSteps,
    /// Divergent transitions (post-warmup).
    Divergences,
    /// NUTS trajectories stopped by the max tree depth (post-warmup).
    MaxTreedepthHits,
    /// ESS-triggered particle resampling events.
    ResampleEvents,
    /// SMC promotions of the particle cloud to the typed fast path.
    TypedPromotions,
    /// SMC demotions back to the boxed path (dynamic structure change).
    TypedDemotions,
    /// Minibatch windows drawn by subsampled VI gradient steps.
    MinibatchWindows,
    /// η candidates tried by the ADVI step-size ladder search.
    EtaTrials,
    /// Lane-batched evaluations (one tilde walk scoring K lanes).
    BatchedEvals,
    /// Lanes summed over batched evaluations (`lanes / evals` = mean K).
    BatchedLanes,
    /// Static-structure promotions: a recorded tilde walk proved stable
    /// and the density is now served by the compiled executor.
    StaticPromotions,
    /// Evaluations a promoted density had to route back to the dynamic
    /// walk (windowed/profiled context, discrete snapshot change).
    StaticDemotions,
    /// Row-batched plate kernel calls made by compiled replays.
    PlateKernelCalls,
    /// Posterior queries answered by the serving runtime.
    ServeQueries,
    /// Serving-cache lookups satisfied by a cached artifact.
    ServeCacheHits,
    /// Serving-cache lookups that required a fresh fit.
    ServeCacheMisses,
    /// Streaming Bayesian updates applied to a cached SMC cloud.
    ServeStreamUpdates,
    /// Streaming updates abandoned for a full refit (ESS collapse).
    ServeEssRefits,
    /// Refits warm-started from a cached posterior (draws or VI params).
    ServeWarmStarts,
    /// Exact closed-form conditional draws made from conjugacy
    /// certificates (Rao-Blackwellized Gibbs blocks).
    ConjugateDraws,
    /// Lint findings emitted by the static model analyzer.
    LintWarnings,
    /// Serving-cache fits avoided by the single-flight guard (waiters
    /// that shared a concurrent leader's fit instead of racing their own).
    ServeSingleFlightWaits,
}

/// Number of counters in the catalog.
pub const N_COUNTERS: usize = 28;

/// Every counter, in [`Counter`] discriminant order.
pub const ALL_COUNTERS: [Counter; N_COUNTERS] = [
    Counter::LogpEvals,
    Counter::GradEvals,
    Counter::RejectedEvals,
    Counter::ArenaEvals,
    Counter::ArenaNodes,
    Counter::ArenaSeeds,
    Counter::LeapfrogSteps,
    Counter::Divergences,
    Counter::MaxTreedepthHits,
    Counter::ResampleEvents,
    Counter::TypedPromotions,
    Counter::TypedDemotions,
    Counter::MinibatchWindows,
    Counter::EtaTrials,
    Counter::BatchedEvals,
    Counter::BatchedLanes,
    Counter::StaticPromotions,
    Counter::StaticDemotions,
    Counter::PlateKernelCalls,
    Counter::ServeQueries,
    Counter::ServeCacheHits,
    Counter::ServeCacheMisses,
    Counter::ServeStreamUpdates,
    Counter::ServeEssRefits,
    Counter::ServeWarmStarts,
    Counter::ConjugateDraws,
    Counter::LintWarnings,
    Counter::ServeSingleFlightWaits,
];

impl Counter {
    /// Stable snake_case key — the field name in `METRICS.json`.
    pub fn key(&self) -> &'static str {
        match self {
            Counter::LogpEvals => "logp_evals",
            Counter::GradEvals => "grad_evals",
            Counter::RejectedEvals => "rejected_evals",
            Counter::ArenaEvals => "arena_evals",
            Counter::ArenaNodes => "arena_nodes",
            Counter::ArenaSeeds => "arena_seeds",
            Counter::LeapfrogSteps => "leapfrog_steps",
            Counter::Divergences => "divergences",
            Counter::MaxTreedepthHits => "max_treedepth_hits",
            Counter::ResampleEvents => "resample_events",
            Counter::TypedPromotions => "typed_promotions",
            Counter::TypedDemotions => "typed_demotions",
            Counter::MinibatchWindows => "minibatch_windows",
            Counter::EtaTrials => "eta_trials",
            Counter::BatchedEvals => "batched_evals",
            Counter::BatchedLanes => "batched_lanes",
            Counter::StaticPromotions => "static_promotions",
            Counter::StaticDemotions => "static_demotions",
            Counter::PlateKernelCalls => "plate_kernel_calls",
            Counter::ServeQueries => "serve_queries",
            Counter::ServeCacheHits => "serve_cache_hits",
            Counter::ServeCacheMisses => "serve_cache_misses",
            Counter::ServeStreamUpdates => "serve_stream_updates",
            Counter::ServeEssRefits => "serve_ess_refits",
            Counter::ServeWarmStarts => "serve_warm_starts",
            Counter::ConjugateDraws => "conjugate_draws",
            Counter::LintWarnings => "lint_warnings",
            Counter::ServeSingleFlightWaits => "serve_single_flight_waits",
        }
    }
}

/// An immutable copy of one thread's counter shard — what a chain run
/// hands back through `SamplerStats.metrics`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MetricsSnapshot {
    counts: [u64; N_COUNTERS],
}

impl MetricsSnapshot {
    pub fn get(&self, c: Counter) -> u64 {
        self.counts[c as usize]
    }

    /// All counters are zero (telemetry off, or nothing instrumented ran).
    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// Element-wise sum — aggregating per-chain snapshots into a run total.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Arena tape nodes per fused backward pass (NaN when none ran).
    pub fn arena_nodes_per_eval(&self) -> f64 {
        let evals = self.get(Counter::ArenaEvals);
        if evals == 0 {
            f64::NAN
        } else {
            self.get(Counter::ArenaNodes) as f64 / evals as f64
        }
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(true) };
    static SHARD: RefCell<MetricsSnapshot> = RefCell::new(MetricsSnapshot::default());
}

/// Whether telemetry is live on this thread: the compile-time `telemetry`
/// feature AND the runtime guard. `cfg!` keeps both sides type-checked
/// while folding the whole call to `false` when the feature is off.
#[inline]
pub fn enabled() -> bool {
    cfg!(feature = "telemetry") && ENABLED.with(|e| e.get())
}

/// Runtime guard for the calling thread (worker threads start enabled).
pub fn set_enabled(on: bool) {
    ENABLED.with(|e| e.set(on));
}

/// Bump a counter by one.
#[inline]
pub fn inc(c: Counter) {
    add(c, 1);
}

/// Bump a counter by `n`.
#[inline]
pub fn add(c: Counter, n: u64) {
    if !enabled() {
        return;
    }
    SHARD.with(|s| s.borrow_mut().counts[c as usize] += n);
}

/// Snapshot-and-reset the calling thread's shard: the drain the chain
/// drivers perform at chain join, scoping counts to one chain run.
pub fn take_local() -> MetricsSnapshot {
    if !cfg!(feature = "telemetry") {
        return MetricsSnapshot::default();
    }
    SHARD.with(|s| std::mem::take(&mut *s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_consistent() {
        assert_eq!(ALL_COUNTERS.len(), N_COUNTERS);
        for (i, c) in ALL_COUNTERS.iter().enumerate() {
            assert_eq!(*c as usize, i, "discriminant order broken at {c:?}");
            assert!(!c.key().is_empty());
        }
        // keys are unique
        for (i, a) in ALL_COUNTERS.iter().enumerate() {
            for b in &ALL_COUNTERS[i + 1..] {
                assert_ne!(a.key(), b.key());
            }
        }
    }

    #[test]
    fn add_take_roundtrip() {
        let _ = take_local(); // isolate from other tests on this thread
        set_enabled(true);
        inc(Counter::LogpEvals);
        add(Counter::ArenaNodes, 40);
        add(Counter::ArenaEvals, 10);
        let snap = take_local();
        assert_eq!(snap.get(Counter::LogpEvals), 1);
        assert_eq!(snap.get(Counter::ArenaNodes), 40);
        assert_eq!(snap.arena_nodes_per_eval(), 4.0);
        assert!(!snap.is_empty());
        // drained: the next snapshot is empty
        assert!(take_local().is_empty());
    }

    #[test]
    fn runtime_guard_blocks_counting() {
        let _ = take_local();
        set_enabled(false);
        inc(Counter::GradEvals);
        add(Counter::LeapfrogSteps, 100);
        assert!(take_local().is_empty());
        set_enabled(true);
    }

    #[test]
    fn merge_sums_elementwise() {
        let _ = take_local();
        set_enabled(true);
        inc(Counter::Divergences);
        let mut a = take_local();
        add(Counter::Divergences, 2);
        inc(Counter::EtaTrials);
        let b = take_local();
        a.merge(&b);
        assert_eq!(a.get(Counter::Divergences), 3);
        assert_eq!(a.get(Counter::EtaTrials), 1);
    }
}
