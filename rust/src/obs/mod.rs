//! Structured telemetry for the sampler stack (the observability layer).
//!
//! Three pieces, threaded through every inference path:
//!
//! - [`metrics`] — a thread-local sharded counter registry (logp/grad
//!   evals, arena nodes+seeds, leapfrog steps, divergences, treedepth
//!   hits, resampling events, typed promotions/demotions, minibatch
//!   windows, η-ladder trials). Chain drivers drain the shard at chain
//!   join into `SamplerStats.metrics`.
//! - [`profile`] — per-tilde-site profiling under [`Context::Profile`]:
//!   wall-clock, logp contribution, and −∞-rejection attribution keyed by
//!   varname, across all four flat executor monomorphizations.
//! - [`report`] — Stan-parity post-run diagnostics (divergences,
//!   treedepth saturation, E-BFMI, low ESS / high R̂, VI η-search
//!   failure) rendered human and exported as `METRICS.json`.
//!
//! Cost discipline: everything is gated on the `telemetry` cargo feature
//! (default-on; `cfg!` folds calls to no-ops when off) plus a per-thread
//! runtime guard ([`metrics::set_enabled`]). Nothing here touches an RNG
//! stream, so seeded draws are bit-identical with telemetry on or off.
//!
//! [`Context::Profile`]: crate::context::Context::Profile

pub mod metrics;
pub mod profile;
pub mod report;

pub use metrics::{Counter, MetricsSnapshot};
pub use profile::{profile_model, SiteProfile};
pub use report::{RunReport, Warning};
