//! The "Stan" comparator: statically compiled, hand-written log-densities
//! with analytic gradients for every Table-1 model (DESIGN.md §7).
//!
//! Stan's advantage in the paper is a statically compiled model with
//! compiled (template-expanded) reverse AD. The equivalent asymptote here
//! is direct Rust code: no trace, no dispatch, no tape — the likelihood
//! gradient is hand-derived, and only the (tiny) constrained↔unconstrained
//! chain rule goes through stack-allocated dual evaluations of the
//! bijector.


pub mod models;

pub use models::stanlike_density;

use crate::ad::forward::Dual;
use crate::ad::Scalar as _;
use crate::dist::{bijector, Domain};

/// Transform helper: given unconstrained coordinates `y` for `domain` and
/// the gradient of the target w.r.t. the **constrained** value, accumulate
/// the gradient w.r.t. `y` (chain rule + ∂ladj/∂y) into `out`, and return
/// the constrained value.
///
/// The Jacobian is evaluated with one dual pass per unconstrained
/// coordinate — per-slot dims are ≤ V−1 = 99 in every benchmark model, so
/// this is negligible against the likelihood work (and fully static).
pub fn pull_back(domain: &Domain, y: &[f64], grad_cons: &[f64], out: &mut [f64]) -> Vec<f64> {
    let m = domain.unconstrained_dim();
    debug_assert_eq!(y.len(), m);
    debug_assert_eq!(out.len(), m);
    // Analytic fast paths for the diagonal transforms — the generic dual
    // path below is O(m²) and would dominate on large Real/Positive slots
    // (EXPERIMENTS.md §Perf: 10,000-D Gaussian stanlike, 922 s → sub-second).
    match domain {
        Domain::Real | Domain::RealVec(_) => {
            for (o, &g) in out.iter_mut().zip(grad_cons) {
                *o += g;
            }
            return y.to_vec();
        }
        Domain::Positive | Domain::PositiveVec(_) => {
            // x = e^y: d/dy [f(x) + ladj] = f'(x)·x + 1
            let mut x = Vec::with_capacity(m);
            for j in 0..m {
                let xj = y[j].exp();
                out[j] += grad_cons[j] * xj + 1.0;
                x.push(xj);
            }
            return x;
        }
        _ => {}
    }
    let mut duals: Vec<Dual> = y.iter().map(|&v| <Dual as crate::ad::Scalar>::constant(v)).collect();
    let mut x_out: Vec<f64> = Vec::new();
    let mut cons_buf: Vec<Dual> = Vec::with_capacity(domain.constrained_dim());
    for j in 0..m {
        duals[j].d = 1.0;
        cons_buf.clear();
        let ladj = bijector::invlink(domain, &duals, &mut cons_buf);
        duals[j].d = 0.0;
        // chain rule: Σ_i grad_cons[i] · dx_i/dy_j + dladj/dy_j
        let mut acc = ladj.d;
        for (i, &g) in grad_cons.iter().enumerate() {
            acc += g * cons_buf[i].d;
        }
        out[j] += acc;
        if j == 0 {
            x_out = cons_buf.iter().map(|d| d.v).collect();
        }
    }
    if m == 0 {
        // discrete or empty: still materialize the constrained value
        let mut cb: Vec<f64> = Vec::new();
        let _ = bijector::invlink(domain, &[], &mut cb);
        return cb;
    }
    x_out
}

/// Constrained value + ladj without gradient.
pub fn push_forward(domain: &Domain, y: &[f64]) -> (Vec<f64>, f64) {
    let mut out = Vec::with_capacity(domain.constrained_dim());
    let ladj = bijector::invlink(domain, y, &mut out);
    (out, ladj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ad::finite_diff_grad;

    #[test]
    fn pull_back_matches_finite_difference() {
        // target: f(x) = Σ i·x_i over the simplex image + ladj
        let domain = Domain::Simplex(4);
        let y = [0.3, -0.5, 0.9];
        let (x, _) = push_forward(&domain, &y);
        let grad_cons: Vec<f64> = (0..4).map(|i| i as f64).collect();
        let mut grad_unc = vec![0.0; 3];
        let got_x = pull_back(&domain, &y, &grad_cons, &mut grad_unc);
        assert_eq!(got_x.len(), 4);
        for (a, b) in got_x.iter().zip(&x) {
            assert!((a - b).abs() < 1e-14);
        }
        let fd = finite_diff_grad(
            |yy| {
                let (x, ladj) = push_forward(&domain, yy);
                x.iter().enumerate().map(|(i, v)| i as f64 * v).sum::<f64>() + ladj
            },
            &y,
            1e-6,
        );
        for (a, b) in grad_unc.iter().zip(&fd) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn pull_back_positive_domain() {
        let domain = Domain::Positive;
        let y = [0.7];
        let mut g = vec![0.0];
        let x = pull_back(&domain, &y, &[2.0], &mut g);
        // x = e^y; d/dy [2x + ladj] = 2e^y + 1
        assert!((x[0] - 0.7f64.exp()).abs() < 1e-14);
        assert!((g[0] - (2.0 * 0.7f64.exp() + 1.0)).abs() < 1e-12);
    }
}
