//! Hand-coded static densities (the Stan comparator) for all 8 Table-1
//! models. Likelihood gradients are fully analytic; the tiny
//! constrained↔unconstrained chain rule uses [`super::pull_back`].

use crate::dist::Domain;
use crate::gradient::LogDensity;
use crate::models::BenchModel;
use crate::runtime::DataInput;
use crate::util::math::{lgamma, sigmoid, LN_2PI, LN_PI};

use super::{pull_back, push_forward};

/// Generic driver: a model described by its slot domains plus a
/// constrained-space logp/grad implementation.
pub struct StanDensity<M: ConsModel> {
    pub model: M,
    domains: Vec<Domain>,
    unc_offsets: Vec<usize>,
    cons_offsets: Vec<usize>,
    dim: usize,
    cons_dim: usize,
}

/// Constrained-space density: everything Stan would compile statically.
pub trait ConsModel: Sync + Send {
    fn domains(&self) -> Vec<Domain>;
    /// logp (excluding Jacobian terms) and gradient w.r.t. constrained
    /// values, accumulated into `grad` (pre-zeroed).
    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64;
}

impl<M: ConsModel> StanDensity<M> {
    pub fn new(model: M) -> Self {
        let domains = model.domains();
        let mut unc_offsets = Vec::with_capacity(domains.len());
        let mut cons_offsets = Vec::with_capacity(domains.len());
        let (mut u, mut c) = (0, 0);
        for d in &domains {
            unc_offsets.push(u);
            cons_offsets.push(c);
            u += d.unconstrained_dim();
            c += d.constrained_dim();
        }
        Self {
            model,
            domains,
            unc_offsets,
            cons_offsets,
            dim: u,
            cons_dim: c,
        }
    }

    fn constrain(&self, theta: &[f64]) -> (Vec<f64>, f64) {
        let mut x = Vec::with_capacity(self.cons_dim);
        let mut ladj = 0.0;
        for (d, &off) in self.domains.iter().zip(&self.unc_offsets) {
            let (xs, la) = push_forward(d, &theta[off..off + d.unconstrained_dim()]);
            x.extend_from_slice(&xs);
            ladj += la;
        }
        (x, ladj)
    }
}

impl<M: ConsModel> LogDensity for StanDensity<M> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn logp(&self, theta: &[f64]) -> f64 {
        let (x, ladj) = self.constrain(theta);
        let mut scratch = vec![0.0; self.cons_dim];
        self.model.logp_grad_cons(&x, &mut scratch) + ladj
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        let (x, ladj) = self.constrain(theta);
        let mut grad_cons = vec![0.0; self.cons_dim];
        let lp = self.model.logp_grad_cons(&x, &mut grad_cons) + ladj;
        let mut grad = vec![0.0; self.dim];
        for (i, d) in self.domains.iter().enumerate() {
            let (uo, un) = (self.unc_offsets[i], d.unconstrained_dim());
            let (co, cn) = (self.cons_offsets[i], d.constrained_dim());
            let _ = pull_back(
                d,
                &theta[uo..uo + un],
                &grad_cons[co..co + cn],
                &mut grad[uo..uo + un],
            );
        }
        (lp, grad)
    }
}

fn f64_data(d: &DataInput) -> Vec<f64> {
    match d {
        DataInput::F64 { data, .. } => data.clone(),
        DataInput::I32 { data, .. } => data.iter().map(|&v| v as f64).collect(),
    }
}

fn i32_data(d: &DataInput) -> Vec<i32> {
    match d {
        DataInput::I32 { data, .. } => data.clone(),
        DataInput::F64 { data, .. } => data.iter().map(|&v| v as i32).collect(),
    }
}

/// Build the hand-coded density matching a benchmark model's data.
pub fn stanlike_density(bm: &BenchModel) -> Box<dyn LogDensity + Send> {
    match bm.name {
        "gaussian_10kd" => Box::new(StanDensity::new(GaussKd { dim: bm.theta_dim })),
        "gauss_unknown" => Box::new(StanDensity::new(GaussUnknown {
            y: f64_data(&bm.data[0]),
        })),
        "naive_bayes" => Box::new(StanDensity::new(NaiveBayes {
            x: f64_data(&bm.data[0]),
            onehot: f64_data(&bm.data[1]),
            c: 10,
            d: 40,
        })),
        "logreg" => Box::new(StanDensity::new(LogReg {
            x: f64_data(&bm.data[0]),
            y: f64_data(&bm.data[1]),
            d: bm.theta_dim,
        })),
        "hier_poisson" => Box::new(StanDensity::new(HierPoisson {
            y: f64_data(&bm.data[0]),
            g: 10,
            m: 5,
        })),
        "sto_volatility" => Box::new(StanDensity::new(StoVol {
            y: f64_data(&bm.data[0]),
        })),
        "hmm_semisup" => Box::new(StanDensity::new(Hmm {
            w: i32_data(&bm.data[0]),
            z: i32_data(&bm.data[1]),
            k: 5,
            v: 20,
        })),
        "lda" => Box::new(StanDensity::new(Lda {
            w: i32_data(&bm.data[0]),
            doc: i32_data(&bm.data[1]),
            k: 5,
            v: 100,
            docs: 10,
        })),
        other => panic!("no stanlike model for {other:?}"),
    }
}

// ---------------------------------------------------------------- T1.1

pub struct GaussKd {
    pub dim: usize,
}

impl ConsModel for GaussKd {
    fn domains(&self) -> Vec<Domain> {
        vec![Domain::RealVec(self.dim)]
    }

    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let mut ss = 0.0;
        for (g, &xi) in grad.iter_mut().zip(x) {
            ss += xi * xi;
            *g += -xi;
        }
        -0.5 * ss - 0.5 * LN_2PI * self.dim as f64
    }
}

// ---------------------------------------------------------------- T1.2

pub struct GaussUnknown {
    pub y: Vec<f64>,
}

impl ConsModel for GaussUnknown {
    fn domains(&self) -> Vec<Domain> {
        vec![Domain::Positive, Domain::Real]
    }

    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let (s, m) = (x[0], x[1]);
        let n = self.y.len() as f64;
        // InverseGamma(2, 3)
        let (a, b): (f64, f64) = (2.0, 3.0);
        let mut lp = a * b.ln() - lgamma(a) - (a + 1.0) * s.ln() - b / s;
        grad[0] += -(a + 1.0) / s + b / (s * s);
        // m ~ Normal(0, √s)
        lp += -0.5 * m * m / s - 0.5 * s.ln() - 0.5 * LN_2PI;
        grad[1] += -m / s;
        grad[0] += 0.5 * m * m / (s * s) - 0.5 / s;
        // y ~ Normal(m, √s)
        let mut ss = 0.0;
        let mut sum_r = 0.0;
        for &yi in &self.y {
            let r = yi - m;
            ss += r * r;
            sum_r += r;
        }
        lp += -0.5 * ss / s - 0.5 * n * s.ln() - 0.5 * n * LN_2PI;
        grad[1] += sum_r / s;
        grad[0] += 0.5 * ss / (s * s) - 0.5 * n / s;
        lp
    }
}

// ---------------------------------------------------------------- T1.3

pub struct NaiveBayes {
    pub x: Vec<f64>,
    pub onehot: Vec<f64>,
    pub c: usize,
    pub d: usize,
}

impl ConsModel for NaiveBayes {
    fn domains(&self) -> Vec<Domain> {
        (0..self.c).map(|_| Domain::RealVec(self.d)).collect()
    }

    fn logp_grad_cons(&self, mu: &[f64], grad: &mut [f64]) -> f64 {
        let (cc, dd) = (self.c, self.d);
        let n = self.x.len() / dd;
        // prior N(0,1)
        let mut lp = 0.0;
        for (g, &m) in grad.iter_mut().zip(mu) {
            lp += -0.5 * m * m;
            *g += -m;
        }
        lp += -0.5 * LN_2PI * (cc * dd) as f64;
        // likelihood
        for i in 0..n {
            let ci = (0..cc)
                .find(|&k| self.onehot[i * cc + k] == 1.0)
                .expect("onehot row without a 1");
            let row = &self.x[i * dd..(i + 1) * dd];
            let mc = &mu[ci * dd..(ci + 1) * dd];
            let gc = &mut grad[ci * dd..(ci + 1) * dd];
            for j in 0..dd {
                let r = row[j] - mc[j];
                lp += -0.5 * r * r;
                gc[j] += r;
            }
        }
        lp - 0.5 * LN_2PI * (n * dd) as f64
    }
}

// ---------------------------------------------------------------- T1.4

pub struct LogReg {
    pub x: Vec<f64>,
    pub y: Vec<f64>,
    pub d: usize,
}

impl ConsModel for LogReg {
    fn domains(&self) -> Vec<Domain> {
        vec![Domain::RealVec(self.d)]
    }

    fn logp_grad_cons(&self, w: &[f64], grad: &mut [f64]) -> f64 {
        let d = self.d;
        let n = self.x.len() / d;
        let mut lp = 0.0;
        for (g, &wi) in grad.iter_mut().zip(w) {
            lp += -0.5 * wi * wi;
            *g += -wi;
        }
        lp += -0.5 * LN_2PI * d as f64;
        for i in 0..n {
            let row = &self.x[i * d..(i + 1) * d];
            let mut logit = 0.0;
            for j in 0..d {
                logit += row[j] * w[j];
            }
            let p = sigmoid(logit);
            let yi = self.y[i];
            // log σ(s·logit), s = 2y−1
            lp += if yi == 1.0 {
                crate::util::math::log_sigmoid(logit)
            } else {
                crate::util::math::log_sigmoid(-logit)
            };
            let coef = yi - p;
            for j in 0..d {
                grad[j] += coef * row[j];
            }
        }
        lp
    }
}

// ---------------------------------------------------------------- T1.5

pub struct HierPoisson {
    pub y: Vec<f64>,
    pub g: usize,
    pub m: usize,
}

impl ConsModel for HierPoisson {
    fn domains(&self) -> Vec<Domain> {
        vec![Domain::Real, Domain::Positive, Domain::RealVec(self.g)]
    }

    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let a0 = x[0];
        let sigma = x[1];
        let b = &x[2..];
        let mut lp = -0.5 * a0 * a0 / 100.0 - (10.0f64).ln() - 0.5 * LN_2PI;
        grad[0] += -a0 / 100.0;
        // σ ~ Exponential(1)
        lp += -sigma;
        grad[1] += -1.0;
        // b ~ N(0, σ)
        for (gi, &bg) in b.iter().enumerate() {
            lp += -0.5 * bg * bg / (sigma * sigma) - sigma.ln() - 0.5 * LN_2PI;
            grad[2 + gi] += -bg / (sigma * sigma);
            grad[1] += bg * bg / (sigma * sigma * sigma) - 1.0 / sigma;
        }
        // y ~ Poisson(exp(a0 + b_g))
        for gi in 0..self.g {
            let eta = a0 + b[gi];
            let lam = eta.exp();
            for mi in 0..self.m {
                let yv = self.y[gi * self.m + mi];
                lp += yv * eta - lam - lgamma(yv + 1.0);
                let d_eta = yv - lam;
                grad[0] += d_eta;
                grad[2 + gi] += d_eta;
            }
        }
        lp
    }
}

// ---------------------------------------------------------------- T1.6

pub struct StoVol {
    pub y: Vec<f64>,
}

impl ConsModel for StoVol {
    fn domains(&self) -> Vec<Domain> {
        let mut d = vec![
            Domain::Interval(-1.0, 1.0),
            Domain::Positive,
            Domain::Real,
        ];
        d.extend((0..self.y.len()).map(|_| Domain::Real));
        d
    }

    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let t_len = self.y.len();
        let (phi, sigma, mu) = (x[0], x[1], x[2]);
        let h = &x[3..];
        let s2 = sigma * sigma;
        let mut lp = 0.0;

        // priors: φ ~ U(-1,1); σ ~ HalfCauchy(2); μ ~ Cauchy(0,10)
        lp += -(2.0f64).ln();
        lp += -(1.0 + (sigma / 2.0).powi(2)).ln() - (2.0f64).ln()
            + (2.0 / std::f64::consts::PI).ln();
        grad[1] += -2.0 * sigma / (4.0 + sigma * sigma);
        lp += -(1.0 + (mu / 10.0).powi(2)).ln() - (10.0f64).ln() - LN_PI;
        grad[2] += -2.0 * mu / (100.0 + mu * mu);

        // h₀ ~ N(μ, sd0), sd0 = σ/√(1−φ²)
        let om = 1.0 - phi * phi;
        let sd0 = sigma / om.sqrt();
        let r0 = h[0] - mu;
        lp += -0.5 * (r0 / sd0).powi(2) - sd0.ln() - 0.5 * LN_2PI;
        let dlp_dsd0 = r0 * r0 / (sd0 * sd0 * sd0) - 1.0 / sd0;
        grad[3] += -r0 / (sd0 * sd0);
        grad[2] += r0 / (sd0 * sd0);
        grad[1] += dlp_dsd0 / om.sqrt();
        grad[0] += dlp_dsd0 * sigma * phi * om.powf(-1.5);

        // h_t ~ N(μ + φ(h_{t−1}−μ), σ)
        for t in 1..t_len {
            let dev = h[t - 1] - mu;
            let r = h[t] - mu - phi * dev;
            lp += -0.5 * r * r / s2 - sigma.ln() - 0.5 * LN_2PI;
            grad[3 + t] += -r / s2;
            grad[3 + t - 1] += phi * r / s2;
            grad[2] += r * (1.0 - phi) / s2;
            grad[0] += r * dev / s2;
            grad[1] += r * r / (s2 * sigma) - 1.0 / sigma;
        }

        // y_t ~ N(0, exp(h_t/2))
        for t in 0..t_len {
            let e = (-h[t]).exp();
            lp += -0.5 * self.y[t] * self.y[t] * e - 0.5 * h[t] - 0.5 * LN_2PI;
            grad[3 + t] += 0.5 * self.y[t] * self.y[t] * e - 0.5;
        }
        lp
    }
}

// ---------------------------------------------------------------- T1.7

pub struct Hmm {
    pub w: Vec<i32>,
    pub z: Vec<i32>,
    pub k: usize,
    pub v: usize,
}

impl ConsModel for Hmm {
    fn domains(&self) -> Vec<Domain> {
        let mut d: Vec<Domain> = (0..self.k).map(|_| Domain::Simplex(self.k)).collect();
        d.extend((0..self.k).map(|_| Domain::Simplex(self.v)));
        d
    }

    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let (kk, vv) = (self.k, self.v);
        let t_sup = self.z.len();
        let t_total = self.w.len();
        let trans = |i: usize, j: usize| x[i * kk + j];
        let emit_off = kk * kk;
        let emit = |i: usize, v_: usize| x[emit_off + i * vv + v_];

        // Dirichlet(1) priors: density = lnΓ(K) per row, zero gradient.
        let mut lp = (0..kk).map(|_| lgamma(kk as f64)).sum::<f64>()
            + (0..kk).map(|_| lgamma(vv as f64)).sum::<f64>();

        // supervised counts → exact gradient n/p
        for t in 0..t_sup {
            let (zt, wt) = (self.z[t] as usize, self.w[t] as usize);
            lp += emit(zt, wt).ln();
            grad[emit_off + zt * vv + wt] += 1.0 / emit(zt, wt);
        }
        for t in 1..t_sup {
            let (a, b) = (self.z[t - 1] as usize, self.z[t] as usize);
            lp += trans(a, b).ln();
            grad[a * kk + b] += 1.0 / trans(a, b);
        }

        // forward pass (log space), storing alphas
        let z_last = self.z[t_sup - 1] as usize;
        let t_un = t_total - t_sup;
        let mut alphas = vec![vec![0.0f64; kk]; t_un];
        for j in 0..kk {
            alphas[0][j] = trans(z_last, j).ln() + emit(j, self.w[t_sup] as usize).ln();
        }
        for t in 1..t_un {
            let wt = self.w[t_sup + t] as usize;
            for j in 0..kk {
                let mut terms = [0.0f64; 16];
                for i in 0..kk {
                    terms[i] = alphas[t - 1][i] + trans(i, j).ln();
                }
                alphas[t][j] =
                    crate::util::math::log_sum_exp(&terms[..kk]) + emit(j, wt).ln();
            }
        }
        let ln_z = crate::util::math::log_sum_exp(&alphas[t_un - 1]);
        lp += ln_z;

        // backward pass for expected counts (gradient of ln Z)
        let mut beta = vec![0.0f64; kk]; // log β_{T-1} = 0
        let mut beta_next = vec![0.0f64; kk];
        // emission counts at the last step
        for j in 0..kk {
            let wt = self.w[t_total - 1] as usize;
            let gamma = (alphas[t_un - 1][j] + beta[j] - ln_z).exp();
            grad[emit_off + j * vv + wt] += gamma / emit(j, wt);
        }
        for t in (0..t_un - 1).rev() {
            let wt1 = self.w[t_sup + t + 1] as usize;
            // β_t(i) = LSE_j [ logT_ij + logE_j(w_{t+1}) + β_{t+1}(j) ]
            for i in 0..kk {
                let mut terms = [0.0f64; 16];
                for j in 0..kk {
                    terms[j] = trans(i, j).ln() + emit(j, wt1).ln() + beta[j];
                }
                beta_next[i] = crate::util::math::log_sum_exp(&terms[..kk]);
            }
            // expected transition counts ξ_t(i,j) and emission counts γ
            for i in 0..kk {
                for j in 0..kk {
                    let xi = (alphas[t][i]
                        + trans(i, j).ln()
                        + emit(j, wt1).ln()
                        + beta[j]
                        - ln_z)
                        .exp();
                    grad[i * kk + j] += xi / trans(i, j);
                }
            }
            for j in 0..kk {
                let gamma = (alphas[t][j] + beta_next[j] - ln_z).exp();
                let wt = self.w[t_sup + t] as usize;
                grad[emit_off + j * vv + wt] += gamma / emit(j, wt);
            }
            std::mem::swap(&mut beta, &mut beta_next);
        }
        // initial-step transition counts from z_last
        // γ_0(j) already counted emissions above; transitions z_last → j:
        for j in 0..kk {
            let xi = (alphas[0][j] + beta[j] - ln_z).exp();
            grad[z_last * kk + j] += xi / trans(z_last, j);
        }
        lp
    }
}

// ---------------------------------------------------------------- T1.8

pub struct Lda {
    pub w: Vec<i32>,
    pub doc: Vec<i32>,
    pub k: usize,
    pub v: usize,
    pub docs: usize,
}

impl ConsModel for Lda {
    fn domains(&self) -> Vec<Domain> {
        let mut d: Vec<Domain> = (0..self.docs).map(|_| Domain::Simplex(self.k)).collect();
        d.extend((0..self.k).map(|_| Domain::Simplex(self.v)));
        d
    }

    fn logp_grad_cons(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let (kk, vv, dd) = (self.k, self.v, self.docs);
        let phi_off = dd * kk;
        let theta = |d_: usize, k_: usize| x[d_ * kk + k_];
        let phi = |k_: usize, w_: usize| x[phi_off + k_ * vv + w_];

        // Dirichlet(1) priors: constants
        let mut lp = dd as f64 * lgamma(kk as f64) + kk as f64 * lgamma(vv as f64);

        for n in 0..self.w.len() {
            let (wn, dn) = (self.w[n] as usize, self.doc[n] as usize);
            let mut p = 0.0;
            for k_ in 0..kk {
                p += theta(dn, k_) * phi(k_, wn);
            }
            lp += p.ln();
            for k_ in 0..kk {
                grad[dn * kk + k_] += phi(k_, wn) / p;
                grad[phi_off + k_ * vv + wn] += theta(dn, k_) / p;
            }
        }
        lp
    }
}

#[cfg(test)]
mod tests {
    use crate::ad::finite_diff_grad;
    use crate::context::Context;
    use crate::model::{init_typed, typed_logp};
    use crate::models::{build_small, ALL_MODELS};
    use crate::util::rng::Xoshiro256pp;

    use super::stanlike_density;
    use crate::gradient::LogDensity;

    /// The hand-coded density must match the DSL model's typed log-density
    /// exactly, and its analytic gradient must match finite differences —
    /// for every benchmark model.
    #[test]
    fn stanlike_matches_dsl_and_fd() {
        for name in ALL_MODELS {
            let bm = build_small(name, 17);
            let stan = stanlike_density(&bm);
            let mut rng = Xoshiro256pp::seed_from_u64(17);
            let tvi = init_typed(bm.model.as_ref(), &mut rng);
            assert_eq!(stan.dim(), tvi.dim(), "{name}: dim");
            let theta: Vec<f64> = (0..tvi.dim())
                .map(|i| 0.07 * ((i % 11) as f64) - 0.3)
                .collect();
            let lp_dsl = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
            let (lp_stan, grad) = stan.logp_grad(&theta);
            let denom = 1.0 + lp_dsl.abs();
            assert!(
                ((lp_dsl - lp_stan) / denom).abs() < 1e-10,
                "{name}: dsl {lp_dsl} vs stan {lp_stan}"
            );
            let fd = finite_diff_grad(|t| stan.logp(t), &theta, 1e-6);
            for i in 0..theta.len() {
                let scale = 1.0 + fd[i].abs();
                assert!(
                    ((grad[i] - fd[i]) / scale).abs() < 1e-4,
                    "{name} grad[{i}]: {} vs fd {}",
                    grad[i],
                    fd[i]
                );
            }
        }
    }
}
