//! Log-density + gradient backends.
//!
//! Samplers only see the [`LogDensity`] trait. Four families implement it:
//!
//! - [`NativeDensity`] — model executed through the **typed** trace with a
//!   Rust AD backend: [`Backend::ReverseFused`] (arena-fused analytic
//!   adjoints — the native default), [`Backend::Forward`] duals, or
//!   [`Backend::Reverse`] tape. The "TypedVarInfo + Julia AD"
//!   configuration of the paper, with the fused engine standing in for
//!   Stan's compiled `_lpdf` varis.
//! - [`UntypedDensity`] — same, through the boxed trace: the
//!   pre-specialization configuration.
//! - `XlaDensity` (in [`crate::runtime`]) — the AOT-compiled artifact:
//!   this reproduction's "Stan-like machine code" path.
//! - [`FnDensity`] — closures; used for the hand-coded Stan-baseline
//!   models in [`crate::stanlike`] and for tests.

use std::sync::{Arc, OnceLock};

use crate::context::Context;
use crate::model::compiled::{self, StaticProgram};
use crate::model::{
    typed_grad_forward, typed_grad_fused, typed_grad_fused_into, typed_grad_reverse, typed_logp,
    untyped_grad_forward, untyped_grad_fused, untyped_grad_fused_into, untyped_grad_reverse,
    untyped_logp, Model,
};
use crate::obs::metrics::{self, Counter};
use crate::varinfo::{TypedVarInfo, UntypedVarInfo};

/// A differentiable target density over unconstrained ℝⁿ.
pub trait LogDensity: Sync {
    fn dim(&self) -> usize;
    fn logp(&self, theta: &[f64]) -> f64;
    /// Value and gradient.
    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>);

    /// Value and gradient into a caller-owned buffer — the leapfrog hot
    /// path. The default delegates to [`LogDensity::logp_grad`] and
    /// copies; allocation-free backends (the arena-fused native engine)
    /// override it to write in place.
    fn logp_grad_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        let (lp, g) = self.logp_grad(theta);
        grad.copy_from_slice(&g);
        lp
    }

    /// Value and gradient for K states at once. `thetas`/`grads` are
    /// lane-major (`[l * dim .. (l+1) * dim]` is lane `l`); `lps` gets the
    /// per-lane log-densities. The default loops [`LogDensity::logp_grad_into`]
    /// per lane; the arena-fused native engine overrides it with one
    /// lane-batched tape walk ([`crate::model::batched`]). Each lane's
    /// result is bit-identical either way, so callers may batch or not
    /// purely on performance grounds.
    fn logp_grad_batch_into(&self, thetas: &[f64], lps: &mut [f64], grads: &mut [f64]) {
        let dim = self.dim();
        let lanes = lps.len();
        assert_eq!(thetas.len(), dim * lanes);
        assert_eq!(grads.len(), dim * lanes);
        for l in 0..lanes {
            lps[l] = self.logp_grad_into(
                &thetas[l * dim..(l + 1) * dim],
                &mut grads[l * dim..(l + 1) * dim],
            );
        }
    }
}

/// Which Rust AD engine a native density uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// Forward duals: n passes per gradient (ForwardDiff.jl analogue).
    Forward,
    /// Reverse tape: one pass, per-op heap nodes (Tracker.jl analogue).
    Reverse,
    /// Arena-fused reverse mode: one pass, one analytic-adjoint kernel per
    /// tilde statement on a capacity-retaining arena (Stan's `_lpdf` vari
    /// design) — the default native engine.
    #[default]
    ReverseFused,
}

impl Backend {
    /// Canonical CLI/bench label — the single naming table every
    /// backend-parsing CLI path goes through (see [`Backend::from_str`]).
    pub fn label(&self) -> &'static str {
        match self {
            Backend::Forward => "forward",
            Backend::Reverse => "tape",
            Backend::ReverseFused => "fused",
        }
    }
}

impl std::str::FromStr for Backend {
    type Err = String;

    /// Parse a native-engine name (the one place CLI backend strings are
    /// mapped to engines; `bench` and `coordinator` both delegate here).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "fused" | "reverse-fused" => Backend::ReverseFused,
            "tape" | "reverse" => Backend::Reverse,
            "forward" | "fwd" => Backend::Forward,
            other => {
                return Err(format!(
                    "unknown gradient backend {other:?} (fused|tape|forward)"
                ))
            }
        })
    }
}

/// Model + typed trace + Rust AD.
///
/// With [`Backend::ReverseFused`], the first full-window `logp_grad_into`
/// attempts a one-time static-structure compilation of the model
/// ([`crate::model::compiled`]). On promotion, subsequent full-window
/// evaluations replay the compiled program — skipping the model body
/// entirely — while windowed/profiled contexts and discrete-trace changes
/// demote transparently (and bit-identically) to the dynamic fused walk.
pub struct NativeDensity<'a> {
    pub model: &'a dyn Model,
    pub tvi: &'a TypedVarInfo,
    pub ctx: Context,
    pub backend: Backend,
    /// Lazily-compiled static program. `None` inside the cell records a
    /// declined compilation (dynamic model, or [`Self::fused_dynamic`]).
    /// The cell sits behind an `Arc` so densities built over one model
    /// artifact from many worker threads can share exactly one compile
    /// ([`Self::fused_shared`]); `OnceLock::get_or_init` makes the first
    /// concurrent evaluation race-safe — one thread records, everyone
    /// else blocks and serves the same program.
    compiled: CompiledCell,
}

/// The shareable compile cell: one static compilation per model artifact,
/// however many per-thread [`NativeDensity`] views exist over it.
pub type CompiledCell = Arc<OnceLock<Option<StaticProgram>>>;

impl<'a> NativeDensity<'a> {
    pub fn new(model: &'a dyn Model, tvi: &'a TypedVarInfo, backend: Backend) -> Self {
        Self {
            model,
            tvi,
            ctx: Context::Default,
            backend,
            compiled: Arc::new(OnceLock::new()),
        }
    }

    /// The default native configuration: arena-fused reverse mode, with
    /// static-structure compilation attempted on first use.
    pub fn fused(model: &'a dyn Model, tvi: &'a TypedVarInfo) -> Self {
        Self::new(model, tvi, Backend::ReverseFused)
    }

    /// A fresh compile cell for [`Self::fused_shared`].
    pub fn shared_cell() -> CompiledCell {
        Arc::new(OnceLock::new())
    }

    /// [`Self::fused`] over a caller-owned compile cell. Every density
    /// built over the same cell — e.g. one per server worker thread, all
    /// viewing one cached model artifact — shares a single static
    /// compilation: exactly one `static_promotions` increment and one
    /// recording walk regardless of how many threads hit their first
    /// evaluation simultaneously, with every thread serving the identical
    /// program (bitwise-identical results by construction).
    pub fn fused_shared(model: &'a dyn Model, tvi: &'a TypedVarInfo, cell: CompiledCell) -> Self {
        Self {
            model,
            tvi,
            ctx: Context::Default,
            backend: Backend::ReverseFused,
            compiled: cell,
        }
    }

    /// Arena-fused reverse mode with static compilation disabled: every
    /// evaluation walks the model body. The baseline the compiled path is
    /// benchmarked (and bitwise-verified) against.
    pub fn fused_dynamic(model: &'a dyn Model, tvi: &'a TypedVarInfo) -> Self {
        let d = Self::fused(model, tvi);
        let _ = d.compiled.set(None);
        d
    }

    /// The promoted program, if compilation has run and succeeded.
    pub fn compiled_program(&self) -> Option<&StaticProgram> {
        self.compiled.get().and_then(|p| p.as_ref())
    }

    /// Resolve the program to serve `ctx`, compiling on first demand.
    /// Returns `None` (→ dynamic walk) for non-servable contexts and
    /// discrete-trace mismatches, counting a demotion whenever a promoted
    /// program had to step aside.
    fn compiled_for(&self, ctx: Context) -> Option<&StaticProgram> {
        if self.backend != Backend::ReverseFused {
            return None;
        }
        if !compiled::servable(ctx) {
            if self.compiled_program().is_some() {
                metrics::inc(Counter::StaticDemotions);
            }
            return None;
        }
        let prog = self
            .compiled
            .get_or_init(|| compiled::try_compile(self.model, self.tvi))
            .as_ref()?;
        if prog.matches_discrete(self.tvi) {
            Some(prog)
        } else {
            metrics::inc(Counter::StaticDemotions);
            None
        }
    }
}

impl<'a> LogDensity for NativeDensity<'a> {
    fn dim(&self) -> usize {
        self.tvi.dim()
    }

    fn logp(&self, theta: &[f64]) -> f64 {
        typed_logp(self.model, self.tvi, theta, self.ctx)
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        match self.backend {
            Backend::Forward => typed_grad_forward(self.model, self.tvi, theta, self.ctx),
            Backend::Reverse => typed_grad_reverse(self.model, self.tvi, theta, self.ctx),
            Backend::ReverseFused => typed_grad_fused(self.model, self.tvi, theta, self.ctx),
        }
    }

    fn logp_grad_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        match self.backend {
            // fused: straight into the caller's buffer, zero allocation
            Backend::ReverseFused => {
                if let Some(prog) = self.compiled_for(self.ctx) {
                    return prog.logp_grad_into(self.tvi, theta, self.ctx, grad);
                }
                typed_grad_fused_into(self.model, self.tvi, theta, self.ctx, grad)
            }
            _ => {
                let (lp, g) = self.logp_grad(theta);
                grad.copy_from_slice(&g);
                lp
            }
        }
    }

    fn logp_grad_batch_into(&self, thetas: &[f64], lps: &mut [f64], grads: &mut [f64]) {
        match self.backend {
            // fused: one K-lane tape walk, bit-identical per lane
            Backend::ReverseFused => {
                if let Some(prog) = self.compiled_for(self.ctx) {
                    let dim = self.tvi.dim();
                    let lanes = lps.len();
                    for l in 0..lanes {
                        lps[l] = prog.logp_grad_into(
                            self.tvi,
                            &thetas[l * dim..(l + 1) * dim],
                            self.ctx,
                            &mut grads[l * dim..(l + 1) * dim],
                        );
                    }
                    metrics::inc(Counter::BatchedEvals);
                    metrics::add(Counter::BatchedLanes, lanes as u64);
                    return;
                }
                crate::model::batched::typed_grad_batch_into(
                    self.model,
                    self.tvi,
                    thetas,
                    lps.len(),
                    self.ctx,
                    lps,
                    grads,
                )
            }
            _ => {
                let dim = self.tvi.dim();
                for l in 0..lps.len() {
                    lps[l] = self.logp_grad_into(
                        &thetas[l * dim..(l + 1) * dim],
                        &mut grads[l * dim..(l + 1) * dim],
                    );
                }
            }
        }
    }
}

/// Model + boxed trace + Rust AD: the dynamic, pre-specialization path.
pub struct UntypedDensity<'a> {
    pub model: &'a dyn Model,
    pub vi: &'a UntypedVarInfo,
    pub ctx: Context,
    pub backend: Backend,
}

impl<'a> UntypedDensity<'a> {
    pub fn new(model: &'a dyn Model, vi: &'a UntypedVarInfo, backend: Backend) -> Self {
        Self {
            model,
            vi,
            ctx: Context::Default,
            backend,
        }
    }
}

impl<'a> LogDensity for UntypedDensity<'a> {
    fn dim(&self) -> usize {
        self.vi.num_unconstrained()
    }

    fn logp(&self, theta: &[f64]) -> f64 {
        untyped_logp(self.model, self.vi, theta, self.ctx)
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        match self.backend {
            Backend::Forward => untyped_grad_forward(self.model, self.vi, theta, self.ctx),
            Backend::Reverse => untyped_grad_reverse(self.model, self.vi, theta, self.ctx),
            Backend::ReverseFused => untyped_grad_fused(self.model, self.vi, theta, self.ctx),
        }
    }

    fn logp_grad_into(&self, theta: &[f64], grad: &mut [f64]) -> f64 {
        match self.backend {
            Backend::ReverseFused => {
                untyped_grad_fused_into(self.model, self.vi, theta, self.ctx, grad)
            }
            _ => {
                let (lp, g) = self.logp_grad(theta);
                grad.copy_from_slice(&g);
                lp
            }
        }
    }
}

/// Closure-backed density (hand-coded models, test fixtures).
pub struct FnDensity<F, G>
where
    F: Fn(&[f64]) -> f64 + Sync,
    G: Fn(&[f64]) -> (f64, Vec<f64>) + Sync,
{
    pub dim: usize,
    pub f: F,
    pub g: G,
}

impl<F, G> LogDensity for FnDensity<F, G>
where
    F: Fn(&[f64]) -> f64 + Sync,
    G: Fn(&[f64]) -> (f64, Vec<f64>) + Sync,
{
    fn dim(&self) -> usize {
        self.dim
    }

    fn logp(&self, theta: &[f64]) -> f64 {
        (self.f)(theta)
    }

    fn logp_grad(&self, theta: &[f64]) -> (f64, Vec<f64>) {
        (self.g)(theta)
    }
}

/// Standard-normal test target.
pub fn std_normal_density(dim: usize) -> impl LogDensity {
    FnDensity {
        dim,
        f: move |th: &[f64]| -0.5 * th.iter().map(|x| x * x).sum::<f64>(),
        g: move |th: &[f64]| {
            (
                -0.5 * th.iter().map(|x| x * x).sum::<f64>(),
                th.iter().map(|x| -x).collect(),
            )
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_labels_roundtrip_through_from_str() {
        for b in [Backend::Forward, Backend::Reverse, Backend::ReverseFused] {
            assert_eq!(b.label().parse::<Backend>(), Ok(b));
        }
        // aliases
        assert_eq!("reverse".parse::<Backend>(), Ok(Backend::Reverse));
        assert_eq!("fwd".parse::<Backend>(), Ok(Backend::Forward));
        assert!("xla".parse::<Backend>().is_err());
        assert_eq!(Backend::default(), Backend::ReverseFused);
    }

    #[test]
    fn fn_density_roundtrip() {
        let d = std_normal_density(3);
        assert_eq!(d.dim(), 3);
        let th = [1.0, -2.0, 0.5];
        assert!((d.logp(&th) + 0.5 * 5.25).abs() < 1e-12);
        let (v, g) = d.logp_grad(&th);
        assert_eq!(v, d.logp(&th));
        assert_eq!(g, vec![-1.0, 2.0, -0.5]);
    }
}
