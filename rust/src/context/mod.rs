//! Execution contexts (paper §3.1) and the log-probability accumulator
//! with early rejection (paper §3.3).
//!
//! Every model run happens in a [`Context`] that decides how each tilde
//! statement contributes to the accumulated log-density:
//!
//! - [`Context::Default`] — log-joint: priors + likelihood.
//! - [`Context::Likelihood`] — observation terms only.
//! - [`Context::Prior`] — parameter terms only.
//! - [`Context::MiniBatch`] — log-joint with the likelihood scaled by
//!   `scale` (= N/batch), so stochastic-VI gradients are unbiased.
//!
//! Rather than four types dispatching at compile time (Julia's design), a
//! context here is a pair of weights applied to the prior- and
//! likelihood-side accumulators — semantically identical, and the weights
//! constant-fold on the typed path.

use crate::ad::Scalar;

/// Which log-density terms a model execution accumulates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Context {
    /// Log-joint of parameters and observations (`DefaultContext`).
    Default,
    /// Only observation (likelihood) terms (`LikelihoodContext`).
    Likelihood,
    /// Only parameter (prior) terms (`PriorContext`).
    Prior,
    /// Log-joint with scaled likelihood (`MiniBatchContext`): the paper's
    /// mechanism for stochastic-gradient VI.
    MiniBatch { scale: f64 },
    /// Replay-with-regenerate particle mode (SMC / Particle-Gibbs): score
    /// only the observe statements with visit index in `[lo, hi)`, drop
    /// all prior-side terms (the bootstrap proposal *is* the prior, so
    /// they cancel in the importance weight). The executor counts observe
    /// statements in model visit order; see `crate::particle`.
    ObsWindow { lo: usize, hi: usize },
}

impl Context {
    /// Weight applied to prior-side (assume) terms, including Jacobian
    /// corrections of linked parameters.
    #[inline]
    pub fn prior_weight(&self) -> f64 {
        match self {
            Context::Likelihood | Context::ObsWindow { .. } => 0.0,
            _ => 1.0,
        }
    }

    /// Weight applied to likelihood-side (observe) terms.
    #[inline]
    pub fn lik_weight(&self) -> f64 {
        match self {
            Context::Prior => 0.0,
            Context::MiniBatch { scale } => *scale,
            _ => 1.0,
        }
    }

    /// The observation-index window scored by this context:
    /// `[0, usize::MAX)` for every non-particle context.
    #[inline]
    pub fn obs_window(&self) -> (usize, usize) {
        match self {
            Context::ObsWindow { lo, hi } => (*lo, *hi),
            _ => (0, usize::MAX),
        }
    }
}

/// Log-density accumulator with the paper's early-rejection flag.
///
/// Calling [`Accumulator::reject`] pins the total at −∞ (the `@logpdf() =
/// -Inf; return` idiom); subsequent accumulation is ignored and model code
/// should return promptly (the `tilde!` macros insert the check).
#[derive(Clone, Copy, Debug)]
pub struct Accumulator<T: Scalar> {
    logp: T,
    rejected: bool,
    prior_w: f64,
    lik_w: f64,
}

impl<T: Scalar> Accumulator<T> {
    pub fn new(ctx: Context) -> Self {
        Self {
            logp: T::constant(0.0),
            rejected: false,
            prior_w: ctx.prior_weight(),
            lik_w: ctx.lik_weight(),
        }
    }

    /// Add a prior-side term (weighted by the context).
    #[inline]
    pub fn add_prior(&mut self, lp: T) {
        if self.rejected {
            return;
        }
        if lp.value() == f64::NEG_INFINITY {
            self.reject();
            return;
        }
        if self.prior_w != 0.0 {
            self.logp = self.logp + lp * self.prior_w;
        }
    }

    /// Add a likelihood-side term (weighted by the context).
    #[inline]
    pub fn add_lik(&mut self, lp: T) {
        if self.rejected {
            return;
        }
        if lp.value() == f64::NEG_INFINITY {
            self.reject();
            return;
        }
        if self.lik_w != 0.0 {
            self.logp = self.logp + lp * self.lik_w;
        }
    }

    /// Early rejection: pin the accumulator at −∞.
    #[inline]
    pub fn reject(&mut self) {
        self.rejected = true;
    }

    #[inline]
    pub fn rejected(&self) -> bool {
        self.rejected
    }

    /// Final value: −∞ if rejected.
    #[inline]
    pub fn total(&self) -> T {
        if self.rejected {
            T::constant(f64::NEG_INFINITY)
        } else {
            self.logp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_accumulates_both() {
        let mut a = Accumulator::<f64>::new(Context::Default);
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -3.0);
    }

    #[test]
    fn likelihood_drops_prior() {
        let mut a = Accumulator::<f64>::new(Context::Likelihood);
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -2.0);
    }

    #[test]
    fn prior_drops_likelihood() {
        let mut a = Accumulator::<f64>::new(Context::Prior);
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -1.0);
    }

    #[test]
    fn minibatch_scales_likelihood_only() {
        let mut a = Accumulator::<f64>::new(Context::MiniBatch { scale: 10.0 });
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -21.0);
    }

    #[test]
    fn reject_pins_neg_inf() {
        let mut a = Accumulator::<f64>::new(Context::Default);
        a.add_prior(-1.0);
        a.reject();
        a.add_lik(-2.0);
        assert!(a.rejected());
        assert_eq!(a.total(), f64::NEG_INFINITY);
    }

    #[test]
    fn neg_inf_term_triggers_rejection() {
        let mut a = Accumulator::<f64>::new(Context::Default);
        a.add_lik(f64::NEG_INFINITY);
        assert!(a.rejected());
        assert_eq!(a.total(), f64::NEG_INFINITY);
    }

    #[test]
    fn weights_expose_paper_semantics() {
        assert_eq!(Context::Default.prior_weight(), 1.0);
        assert_eq!(Context::Default.lik_weight(), 1.0);
        assert_eq!(Context::Likelihood.prior_weight(), 0.0);
        assert_eq!(Context::Prior.lik_weight(), 0.0);
        assert_eq!(Context::MiniBatch { scale: 5.0 }.lik_weight(), 5.0);
    }

    #[test]
    fn obs_window_context_drops_priors_and_exposes_window() {
        let ctx = Context::ObsWindow { lo: 3, hi: 7 };
        assert_eq!(ctx.prior_weight(), 0.0);
        assert_eq!(ctx.lik_weight(), 1.0);
        assert_eq!(ctx.obs_window(), (3, 7));
        assert_eq!(Context::Default.obs_window(), (0, usize::MAX));
    }
}
