//! Execution contexts (paper §3.1) and the log-probability accumulator
//! with early rejection (paper §3.3).
//!
//! Every model run happens in a [`Context`] that decides how each tilde
//! statement contributes to the accumulated log-density:
//!
//! - [`Context::Default`] — log-joint: priors + likelihood.
//! - [`Context::Likelihood`] — observation terms only.
//! - [`Context::Prior`] — parameter terms only.
//! - [`Context::MiniBatch`] — log-joint with the likelihood scaled by
//!   `scale` (= N/batch), so stochastic-VI gradients are unbiased.
//! - [`Context::Subsample`] — log-joint with the likelihood *restricted*
//!   to an observation-index window and scaled: the tall-data estimator
//!   (priors at weight 1 + a random batch of observations at N/B).
//! - [`Context::ObsWindow`] — particle replay: windowed likelihood, no
//!   prior terms.
//!
//! Rather than distinct types dispatching at compile time (Julia's
//! design), a context here is a pair of weights applied to the prior- and
//! likelihood-side accumulators plus an observation-index window —
//! semantically identical, and the weights constant-fold on the typed
//! path. `Subsample` generalizes `MiniBatch` (full window) and the
//! likelihood half of `ObsWindow` (scale 1, but with priors kept).
//!
//! [`Context::SubsampleIdx`] extends `Subsample` to **non-contiguous**
//! observation-index sets (importance-sampled or without-replacement
//! minibatches). Because `Context` must stay `Copy` (it is embedded in
//! every density and cloned per evaluation), the index set itself lives in
//! a process-global registry and the context carries only a [`SubsetId`]
//! handle — see [`register_subset`].

use std::sync::{Arc, Mutex, OnceLock};

use crate::ad::Scalar;

/// Copyable handle to a registered observation-index set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubsetId(u32);

static SUBSETS: OnceLock<Mutex<Vec<Arc<[u32]>>>> = OnceLock::new();

fn subset_registry() -> &'static Mutex<Vec<Arc<[u32]>>> {
    SUBSETS.get_or_init(|| Mutex::new(Vec::new()))
}

/// Register an observation-index set for [`Context::SubsampleIdx`]. The
/// indices are sorted and deduplicated; the returned handle is `Copy` and
/// valid for the life of the process. Registration is intended for
/// per-fit (not per-step) sets — entries are never reclaimed.
pub fn register_subset(mut idx: Vec<u32>) -> SubsetId {
    idx.sort_unstable();
    idx.dedup();
    let mut reg = subset_registry().lock().expect("subset registry poisoned");
    reg.push(idx.into());
    SubsetId((reg.len() - 1) as u32)
}

/// The sorted, deduplicated indices behind a handle.
pub fn subset_indices(id: SubsetId) -> Arc<[u32]> {
    subset_registry().lock().expect("subset registry poisoned")[id.0 as usize].clone()
}

/// Which log-density terms a model execution accumulates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Context {
    /// Log-joint of parameters and observations (`DefaultContext`).
    Default,
    /// Only observation (likelihood) terms (`LikelihoodContext`).
    Likelihood,
    /// Only parameter (prior) terms (`PriorContext`).
    Prior,
    /// Log-joint with scaled likelihood (`MiniBatchContext`): the paper's
    /// mechanism for stochastic-gradient VI. Equivalent to
    /// [`Context::Subsample`] with the full observation window.
    MiniBatch { scale: f64 },
    /// Log-joint with the likelihood restricted to observe statements with
    /// visit index in `[lo, hi)` and scaled by `scale` (= N/B): priors at
    /// weight 1 + a minibatch of observations — the unbiased estimator
    /// stochastic VI needs on tall-data models. Out-of-window observations
    /// contribute nothing (and cannot trigger early rejection).
    Subsample { lo: usize, hi: usize, scale: f64 },
    /// Log-joint with the likelihood restricted to an arbitrary
    /// (non-contiguous) set of observation visit indices, scaled by
    /// `scale`: the without-replacement / importance-sampled minibatch
    /// estimator. The set is registered once via [`register_subset`]; the
    /// accumulator walks it with a lazy cursor, so membership tests are
    /// O(1) amortized over a model pass.
    SubsampleIdx { set: SubsetId, scale: f64 },
    /// Replay-with-regenerate particle mode (SMC / Particle-Gibbs): score
    /// only the observe statements with visit index in `[lo, hi)`, drop
    /// all prior-side terms (the bootstrap proposal *is* the prior, so
    /// they cancel in the importance weight). The executor counts observe
    /// statements in model visit order; see `crate::particle`.
    ObsWindow { lo: usize, hi: usize },
    /// Instrumented log-joint (weights identical to [`Context::Default`]):
    /// every flat executor additionally records one `obs::profile` row per
    /// tilde statement — wall-clock, the site's own logp contribution, and
    /// −∞-rejection attribution. The contextual-dispatch showcase; see
    /// `crate::obs::profile`.
    Profile,
}

impl Context {
    /// Weight applied to prior-side (assume) terms, including Jacobian
    /// corrections of linked parameters.
    #[inline]
    pub fn prior_weight(&self) -> f64 {
        match self {
            Context::Likelihood | Context::ObsWindow { .. } => 0.0,
            _ => 1.0,
        }
    }

    /// Weight applied to likelihood-side (observe) terms inside the
    /// observation window.
    #[inline]
    pub fn lik_weight(&self) -> f64 {
        match self {
            Context::Prior => 0.0,
            Context::MiniBatch { scale } => *scale,
            Context::Subsample { scale, .. } => *scale,
            Context::SubsampleIdx { scale, .. } => *scale,
            _ => 1.0,
        }
    }

    /// The observation-index window scored by this context:
    /// `[0, usize::MAX)` for every non-windowed context. A
    /// [`Context::SubsampleIdx`] set is *not* a contiguous window: it
    /// reports the full range, so window-aware model bodies visit every
    /// site and the accumulator's cursor does the membership filtering.
    #[inline]
    pub fn obs_window(&self) -> (usize, usize) {
        match self {
            Context::ObsWindow { lo, hi } => (*lo, *hi),
            Context::Subsample { lo, hi, .. } => (*lo, *hi),
            _ => (0, usize::MAX),
        }
    }
}

/// Log-density accumulator with the paper's early-rejection flag.
///
/// Calling [`Accumulator::reject`] pins the total at −∞ (the `@logpdf() =
/// -Inf; return` idiom); subsequent accumulation is ignored and model code
/// should return promptly (the `tilde!` macros insert the check).
///
/// The accumulator also owns the context's **observation-site counter**:
/// executors route observe statements through [`Accumulator::add_obs`]
/// (or [`Accumulator::note_obs`] on the fused path), which counts sites
/// in model visit order and drops terms outside the context's window —
/// so `Context::Subsample` works identically on every executor.
#[derive(Clone, Debug)]
pub struct Accumulator<T: Scalar> {
    logp: T,
    rejected: bool,
    prior_w: f64,
    lik_w: f64,
    obs_lo: usize,
    obs_hi: usize,
    obs_seen: usize,
    /// Non-contiguous index set ([`Context::SubsampleIdx`]), sorted and
    /// deduplicated, with a lazy cursor: `obs_seen` only ever increases,
    /// so each `note_obs` advances `idx_pos` monotonically — O(|set|)
    /// total cursor work per model pass.
    idx_set: Option<Arc<[u32]>>,
    idx_pos: usize,
}

impl<T: Scalar> Accumulator<T> {
    pub fn new(ctx: Context) -> Self {
        let (obs_lo, obs_hi) = ctx.obs_window();
        let idx_set = match ctx {
            Context::SubsampleIdx { set, .. } => Some(subset_indices(set)),
            _ => None,
        };
        Self {
            logp: T::constant(0.0),
            rejected: false,
            prior_w: ctx.prior_weight(),
            lik_w: ctx.lik_weight(),
            obs_lo,
            obs_hi,
            obs_seen: 0,
            idx_set,
            idx_pos: 0,
        }
    }

    /// Add a prior-side term (weighted by the context). A −∞ prior term
    /// rejects even at weight 0: particle replay relies on zero-weighted
    /// proposal priors still vetoing impossible draws.
    #[inline]
    pub fn add_prior(&mut self, lp: T) {
        if self.rejected {
            return;
        }
        if lp.value() == f64::NEG_INFINITY {
            self.reject();
            return;
        }
        if self.prior_w != 0.0 {
            self.logp = self.logp + lp * self.prior_w;
        }
    }

    /// Add a likelihood-side term at an explicit weight. A zero weight
    /// skips the term entirely — including the −∞ rejection check, so a
    /// prior-only evaluation (or an out-of-window observation) is never
    /// poisoned by an impossible observation.
    #[inline]
    pub fn add_lik_weighted(&mut self, lp: T, w: f64) {
        if self.rejected || w == 0.0 {
            return;
        }
        if lp.value() == f64::NEG_INFINITY {
            self.reject();
            return;
        }
        self.logp = self.logp + lp * w;
    }

    /// Add a likelihood-side term (weighted by the context), without
    /// observation-site counting — the replay executors do their own
    /// windowing and route pre-windowed terms here.
    #[inline]
    pub fn add_lik(&mut self, lp: T) {
        self.add_lik_weighted(lp, self.lik_w);
    }

    /// Count one observation site (model visit order) and return the
    /// weight its term carries: `lik_weight()` inside the context's
    /// window, 0.0 outside. Fused executors call this *before* evaluating
    /// the density kernel so out-of-window observations cost nothing.
    #[inline]
    pub fn note_obs(&mut self) -> f64 {
        let i = self.obs_seen;
        self.obs_seen += 1;
        if let Some(set) = &self.idx_set {
            // lazy cursor: skip_obs only advances obs_seen, so catch the
            // cursor up to the current site before the membership test
            while self.idx_pos < set.len() && (set[self.idx_pos] as usize) < i {
                self.idx_pos += 1;
            }
            if self.idx_pos < set.len() && set[self.idx_pos] as usize == i {
                self.idx_pos += 1;
                return self.lik_w;
            }
            return 0.0;
        }
        if i >= self.obs_lo && i < self.obs_hi {
            self.lik_w
        } else {
            0.0
        }
    }

    /// Skip `n` observation sites without scoring them (they still count
    /// toward the window indices) — the hook window-aware model bodies
    /// use to jump over out-of-window blocks.
    #[inline]
    pub fn skip_obs(&mut self, n: usize) {
        self.obs_seen += n;
    }

    /// Count + window + weight + accumulate one observation term: the
    /// one-call form the non-fused executors use.
    #[inline]
    pub fn add_obs(&mut self, lp: T) {
        let w = self.note_obs();
        self.add_lik_weighted(lp, w);
    }

    /// Observation sites counted so far (visited or skipped).
    #[inline]
    pub fn obs_seen(&self) -> usize {
        self.obs_seen
    }

    /// Early rejection: pin the accumulator at −∞.
    #[inline]
    pub fn reject(&mut self) {
        self.rejected = true;
    }

    #[inline]
    pub fn rejected(&self) -> bool {
        self.rejected
    }

    /// Final value: −∞ if rejected.
    #[inline]
    pub fn total(&self) -> T {
        if self.rejected {
            T::constant(f64::NEG_INFINITY)
        } else {
            self.logp
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_accumulates_both() {
        let mut a = Accumulator::<f64>::new(Context::Default);
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -3.0);
    }

    #[test]
    fn likelihood_drops_prior() {
        let mut a = Accumulator::<f64>::new(Context::Likelihood);
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -2.0);
    }

    #[test]
    fn prior_drops_likelihood() {
        let mut a = Accumulator::<f64>::new(Context::Prior);
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -1.0);
    }

    #[test]
    fn minibatch_scales_likelihood_only() {
        let mut a = Accumulator::<f64>::new(Context::MiniBatch { scale: 10.0 });
        a.add_prior(-1.0);
        a.add_lik(-2.0);
        assert_eq!(a.total(), -21.0);
    }

    #[test]
    fn reject_pins_neg_inf() {
        let mut a = Accumulator::<f64>::new(Context::Default);
        a.add_prior(-1.0);
        a.reject();
        a.add_lik(-2.0);
        assert!(a.rejected());
        assert_eq!(a.total(), f64::NEG_INFINITY);
    }

    #[test]
    fn neg_inf_term_triggers_rejection() {
        let mut a = Accumulator::<f64>::new(Context::Default);
        a.add_lik(f64::NEG_INFINITY);
        assert!(a.rejected());
        assert_eq!(a.total(), f64::NEG_INFINITY);
    }

    #[test]
    fn zero_weight_neg_inf_likelihood_does_not_reject() {
        // regression: a prior-only evaluation must not be poisoned by an
        // impossible observation — the zero-weighted term is skipped
        // before the −∞ check
        let mut a = Accumulator::<f64>::new(Context::Prior);
        a.add_prior(-1.0);
        a.add_lik(f64::NEG_INFINITY);
        a.add_obs(f64::NEG_INFINITY);
        assert!(!a.rejected());
        assert_eq!(a.total(), -1.0);
    }

    #[test]
    fn zero_weight_neg_inf_prior_still_rejects() {
        // particle replay routes zero-weighted proposal priors through
        // add_prior precisely so impossible draws veto the particle
        let mut a = Accumulator::<f64>::new(Context::Likelihood);
        a.add_prior(f64::NEG_INFINITY);
        assert!(a.rejected());
    }

    #[test]
    fn weights_expose_paper_semantics() {
        assert_eq!(Context::Default.prior_weight(), 1.0);
        assert_eq!(Context::Default.lik_weight(), 1.0);
        assert_eq!(Context::Likelihood.prior_weight(), 0.0);
        assert_eq!(Context::Prior.lik_weight(), 0.0);
        assert_eq!(Context::MiniBatch { scale: 5.0 }.lik_weight(), 5.0);
        // Profile is Default plus instrumentation: same weights, full window
        assert_eq!(Context::Profile.prior_weight(), 1.0);
        assert_eq!(Context::Profile.lik_weight(), 1.0);
        assert_eq!(Context::Profile.obs_window(), (0, usize::MAX));
    }

    #[test]
    fn obs_window_context_drops_priors_and_exposes_window() {
        let ctx = Context::ObsWindow { lo: 3, hi: 7 };
        assert_eq!(ctx.prior_weight(), 0.0);
        assert_eq!(ctx.lik_weight(), 1.0);
        assert_eq!(ctx.obs_window(), (3, 7));
        assert_eq!(Context::Default.obs_window(), (0, usize::MAX));
    }

    #[test]
    fn subsample_keeps_priors_and_windows_scaled_likelihood() {
        let ctx = Context::Subsample { lo: 1, hi: 3, scale: 4.0 };
        assert_eq!(ctx.prior_weight(), 1.0);
        assert_eq!(ctx.lik_weight(), 4.0);
        assert_eq!(ctx.obs_window(), (1, 3));
        let mut a = Accumulator::<f64>::new(ctx);
        a.add_prior(-1.0);
        a.add_obs(-10.0); // site 0: out of window
        a.add_obs(-2.0); // site 1: scored × 4
        a.add_obs(-3.0); // site 2: scored × 4
        a.add_obs(-10.0); // site 3: out of window
        assert_eq!(a.obs_seen(), 4);
        assert_eq!(a.total(), -1.0 - 4.0 * 5.0);
    }

    #[test]
    fn skip_obs_advances_window_indices() {
        let ctx = Context::Subsample { lo: 2, hi: 4, scale: 2.0 };
        let mut a = Accumulator::<f64>::new(ctx);
        a.skip_obs(2); // sites 0-1 jumped without evaluation
        a.add_obs(-1.0); // site 2: scored
        a.add_obs(-2.0); // site 3: scored
        a.skip_obs(5);
        assert_eq!(a.obs_seen(), 9);
        assert_eq!(a.total(), -6.0);
        // out-of-window −∞ observations never poison the run
        let mut b = Accumulator::<f64>::new(ctx);
        b.add_obs(f64::NEG_INFINITY);
        assert!(!b.rejected());
    }

    #[test]
    fn subsample_idx_scores_exactly_the_set() {
        let set = register_subset(vec![1, 3, 3, 0]); // dedup + sort → {0, 1, 3}
        let ctx = Context::SubsampleIdx { set, scale: 2.0 };
        assert_eq!(ctx.prior_weight(), 1.0);
        assert_eq!(ctx.lik_weight(), 2.0);
        assert_eq!(ctx.obs_window(), (0, usize::MAX));
        let mut a = Accumulator::<f64>::new(ctx);
        a.add_prior(-1.0);
        a.add_obs(-1.0); // site 0: in set, × 2
        a.add_obs(-10.0); // site 1: in set, × 2
        a.add_obs(-100.0); // site 2: out of set
        a.add_obs(-2.0); // site 3: in set, × 2
        a.add_obs(-100.0); // site 4: out of set
        assert_eq!(a.obs_seen(), 5);
        assert_eq!(a.total(), -1.0 - 2.0 * 13.0);
        // out-of-set −∞ observations never poison the run
        let mut b = Accumulator::<f64>::new(ctx);
        b.add_obs(-1.0);
        b.add_obs(-1.0);
        b.add_obs(f64::NEG_INFINITY);
        assert!(!b.rejected());
    }

    #[test]
    fn subsample_idx_cursor_survives_skip_obs() {
        let set = register_subset(vec![2, 5]);
        let ctx = Context::SubsampleIdx { set, scale: 3.0 };
        let mut a = Accumulator::<f64>::new(ctx);
        a.skip_obs(2); // jump past sites 0-1 without touching the cursor
        a.add_obs(-1.0); // site 2: in set
        a.skip_obs(2); // sites 3-4
        a.add_obs(-2.0); // site 5: in set
        a.add_obs(-50.0); // site 6: out of set
        assert_eq!(a.obs_seen(), 7);
        assert_eq!(a.total(), -9.0);
        // skipping over in-set sites drops their terms, same as a
        // contiguous window jumped by skip_obs
        let mut b = Accumulator::<f64>::new(ctx);
        b.skip_obs(6);
        b.add_obs(-50.0); // site 6: out of set
        assert_eq!(b.total(), 0.0);
    }

    #[test]
    fn subset_registry_roundtrips_sorted_unique() {
        let id = register_subset(vec![9, 4, 4, 7]);
        assert_eq!(&*subset_indices(id), &[4, 7, 9]);
        let id2 = register_subset(Vec::new());
        assert!(subset_indices(id2).is_empty());
        assert_ne!(id, id2);
    }

    #[test]
    fn minibatch_matches_full_window_subsample() {
        let mb = Context::MiniBatch { scale: 3.0 };
        let ss = Context::Subsample { lo: 0, hi: usize::MAX, scale: 3.0 };
        let mut a = Accumulator::<f64>::new(mb);
        let mut b = Accumulator::<f64>::new(ss);
        for acc in [&mut a, &mut b] {
            acc.add_prior(-1.5);
            acc.add_obs(-2.0);
            acc.add_obs(-0.5);
        }
        assert_eq!(a.total(), b.total());
    }
}
