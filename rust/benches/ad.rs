//! `cargo bench --bench ad` — the §4 AD analysis: gradient-engine cost on
//! the workload classes the paper discusses.
//!
//! Compares the arena-fused engine (Stan-style analytic adjoints, the
//! native default), forward duals (ForwardDiff analogue), the per-op
//! reverse tape (Tracker analogue) and the hand-coded static gradient
//! (Stan analogue) on: a vectorized model (logreg), and the two
//! scalar-loop time-series models (sto_volatility, hmm_semisup) where the
//! paper measured Tracker.jl's dynamic-dispatch overhead dominating.
//!
//! Also a perf-regression harness: it asserts that the reverse tape reuses
//! its adjoint scratch and that the fused arena reaches zero steady-state
//! allocation (capacities must be bit-stable across repeated gradients).

use dynamicppl::context::Context;
use dynamicppl::gradient::LogDensity;
use dynamicppl::model::{
    init_typed, typed_grad_forward, typed_grad_fused_into, typed_grad_reverse,
};
use dynamicppl::models::build_small;
use dynamicppl::stanlike::stanlike_density;
use dynamicppl::util::rng::Xoshiro256pp;
use dynamicppl::util::timing::{bench_micro, render_table, Measurement};

fn main() {
    let mut rows: Vec<Measurement> = Vec::new();
    let mut ratios = Vec::new();

    for name in ["logreg", "sto_volatility", "hmm_semisup"] {
        let bm = build_small(name, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
        let dim = theta.len();
        let mut grad = vec![0.0; dim];

        rows.push(bench_micro(&format!("{name}/fused"), 5e-3, 5, || {
            std::hint::black_box(typed_grad_fused_into(
                bm.model.as_ref(),
                &tvi,
                &theta,
                Context::Default,
                &mut grad,
            ));
        }));
        rows.push(bench_micro(&format!("{name}/tape"), 5e-3, 5, || {
            std::hint::black_box(typed_grad_reverse(
                bm.model.as_ref(),
                &tvi,
                &theta,
                Context::Default,
            ));
        }));
        // forward is O(dim) passes — only bench on small dims
        if dim <= 60 {
            rows.push(bench_micro(&format!("{name}/forward"), 5e-3, 5, || {
                std::hint::black_box(typed_grad_forward(
                    bm.model.as_ref(),
                    &tvi,
                    &theta,
                    Context::Default,
                ));
            }));
        }
        let stan = stanlike_density(&bm);
        rows.push(bench_micro(&format!("{name}/static"), 5e-3, 5, || {
            std::hint::black_box(stan.logp_grad(&theta));
        }));

        // ---- allocation-regression asserts -----------------------------
        // (1) the reverse tape's backward must reuse its adjoint scratch
        let _ = typed_grad_reverse(bm.model.as_ref(), &tvi, &theta, Context::Default);
        let tape_scratch = dynamicppl::ad::reverse::adjoint_scratch_capacity();
        assert!(tape_scratch > 0, "{name}: adjoint scratch not in use");
        for _ in 0..5 {
            let _ = typed_grad_reverse(bm.model.as_ref(), &tvi, &theta, Context::Default);
        }
        assert_eq!(
            dynamicppl::ad::reverse::adjoint_scratch_capacity(),
            tape_scratch,
            "{name}: reverse::backward reallocated its adjoint buffer"
        );
        // (2) the fused arena must be at zero steady-state allocation
        let arena_cap = dynamicppl::ad::arena::capacity_bytes();
        for _ in 0..5 {
            let _ = typed_grad_fused_into(
                bm.model.as_ref(),
                &tvi,
                &theta,
                Context::Default,
                &mut grad,
            );
        }
        assert_eq!(
            dynamicppl::ad::arena::capacity_bytes(),
            arena_cap,
            "{name}: fused arena allocated at steady state"
        );

        let pick = |suffix: &str| {
            rows.iter()
                .find(|m| m.name == format!("{name}/{suffix}"))
                .map(|m| m.mean())
        };
        let tape = pick("tape").unwrap();
        let stat = pick("static").unwrap();
        let fused = pick("fused").unwrap();
        ratios.push((name, tape / stat, tape / fused, fused / stat));

        // (3) node-throughput tripwire: one fused gradient (forward walk
        // + backward sweep with the contiguous diagonal-run fast path)
        // must process its tape nodes + seeds well above dispatch-bound
        // speeds. The floor is deliberately loose — it catches a gross
        // backward-sweep regression, not benchmark noise.
        let stats = dynamicppl::ad::arena::last_stats();
        let nodes_per_sec = (stats.nodes + stats.seeds).max(1) as f64 / fused;
        assert!(
            nodes_per_sec > 1e6,
            "{name}: arena node throughput regressed to {nodes_per_sec:.0} nodes/s \
             ({} nodes + {} seeds at {fused:.2e}s per gradient)",
            stats.nodes,
            stats.seeds
        );
    }

    println!("{}", render_table("gradient cost per evaluation", &rows));
    println!("engine overhead vs the static (Stan-analogue) gradient:");
    println!(
        "{:<16} {:>14} {:>14} {:>16}",
        "model", "tape/static", "tape/fused", "fused/static"
    );
    for (name, ts, tf, fs) in &ratios {
        println!("{name:<16} {ts:>13.1}× {tf:>13.1}× {fs:>15.1}×");
    }
    println!(
        "\nNote: hmm_semisup's static baseline runs a full forward-backward\n\
         (expected-count) pass — a different, costlier algorithm than taping\n\
         the forward recursion — so its ratio is not a pure dispatch tax.\n\
         The tape column is the paper's §4 Tracker.jl finding; the fused\n\
         column is how much of that tax the arena engine recovers without\n\
         leaving native code (the rest is the XLA/AOT artifact's territory)."
    );
}
