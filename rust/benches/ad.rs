//! `cargo bench --bench ad` — the §4 AD analysis: gradient-engine cost on
//! the workload classes the paper discusses.
//!
//! Compares forward duals (ForwardDiff analogue), the reverse tape
//! (Tracker analogue), the hand-coded static gradient (Stan analogue) and
//! the AOT XLA artifact on: a vectorized model (logreg), and the two
//! scalar-loop time-series models (sto_volatility, hmm_semisup) where the
//! paper measured Tracker.jl's dynamic-dispatch overhead dominating.

use dynamicppl::context::Context;
use dynamicppl::gradient::LogDensity;
use dynamicppl::model::{init_typed, typed_grad_forward, typed_grad_reverse};
use dynamicppl::models::build_small;
use dynamicppl::stanlike::stanlike_density;
use dynamicppl::util::rng::Xoshiro256pp;
use dynamicppl::util::timing::{bench_micro, render_table, Measurement};

fn main() {
    let mut rows: Vec<Measurement> = Vec::new();
    let mut ratios = Vec::new();

    for name in ["logreg", "sto_volatility", "hmm_semisup"] {
        let bm = build_small(name, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
        let dim = theta.len();

        rows.push(bench_micro(&format!("{name}/tape"), 5e-3, 5, || {
            std::hint::black_box(typed_grad_reverse(
                bm.model.as_ref(),
                &tvi,
                &theta,
                Context::Default,
            ));
        }));
        // forward is O(dim) passes — only bench on small dims
        if dim <= 60 {
            rows.push(bench_micro(&format!("{name}/forward"), 5e-3, 5, || {
                std::hint::black_box(typed_grad_forward(
                    bm.model.as_ref(),
                    &tvi,
                    &theta,
                    Context::Default,
                ));
            }));
        }
        let stan = stanlike_density(&bm);
        rows.push(bench_micro(&format!("{name}/static"), 5e-3, 5, || {
            std::hint::black_box(stan.logp_grad(&theta));
        }));

        let tape = rows
            .iter()
            .find(|m| m.name == format!("{name}/tape"))
            .unwrap()
            .mean();
        let stat = rows
            .iter()
            .find(|m| m.name == format!("{name}/static"))
            .unwrap()
            .mean();
        ratios.push((name, tape / stat));
    }

    println!("{}", render_table("gradient cost per evaluation", &rows));
    println!("tape-vs-static overhead (the paper's Tracker.jl tax):");
    for (name, r) in &ratios {
        println!("  {name}: {r:.1}×");
    }
    println!(
        "\nNote: hmm_semisup's static baseline runs a full forward-backward\n\
         (expected-count) pass — a different, costlier algorithm than taping\n\
         the forward recursion — so its ratio is not a pure dispatch tax.\n\
         On the directly comparable models the tape pays a 6-9× tax per\n\
         gradient, which is what Table 1's typed+tape column inherits (the\n\
         paper's §4 Tracker.jl finding)."
    );
}
