//! `cargo bench --bench varinfo` — the §2.2 ablation: what does trace
//! specialization actually buy?
//!
//! Micro-benchmarks of the boxed (UntypedVarInfo) vs flat (TypedVarInfo)
//! trace on identical models: full log-density evaluations, trace
//! construction, specialization, and link/invlink round-trips.

use dynamicppl::context::Context;
use dynamicppl::model::{init_trace, typed_logp, untyped_logp};
use dynamicppl::models::{build_small, ALL_MODELS};
use dynamicppl::util::rng::Xoshiro256pp;
use dynamicppl::util::timing::{bench_micro, render_table, Measurement};
use dynamicppl::varinfo::TypedVarInfo;

fn main() {
    let mut rows: Vec<Measurement> = Vec::new();

    for name in ["gauss_unknown", "logreg", "sto_volatility", "lda"] {
        let bm = build_small(name, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let vi = init_trace(bm.model.as_ref(), &mut rng);
        let tvi = TypedVarInfo::from_untyped(&vi);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.5).collect();

        rows.push(bench_micro(&format!("{name}/logp untyped"), 5e-3, 5, || {
            std::hint::black_box(untyped_logp(
                bm.model.as_ref(),
                &vi,
                &theta,
                Context::Default,
            ));
        }));
        rows.push(bench_micro(&format!("{name}/logp typed"), 5e-3, 5, || {
            std::hint::black_box(typed_logp(
                bm.model.as_ref(),
                &tvi,
                &theta,
                Context::Default,
            ));
        }));
    }

    // trace lifecycle costs
    for name in ALL_MODELS {
        let bm = build_small(name, 5);
        rows.push(bench_micro(&format!("{name}/init_trace"), 5e-3, 3, || {
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            std::hint::black_box(init_trace(bm.model.as_ref(), &mut rng));
        }));
    }
    {
        let bm = build_small("lda", 5);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let vi = init_trace(bm.model.as_ref(), &mut rng);
        rows.push(bench_micro("lda/specialize", 5e-3, 5, || {
            std::hint::black_box(TypedVarInfo::from_untyped(&vi));
        }));
        let mut tvi = TypedVarInfo::from_untyped(&vi);
        let theta = tvi.unconstrained.clone();
        rows.push(bench_micro("lda/set_unconstrained", 5e-3, 5, || {
            tvi.set_unconstrained(std::hint::black_box(&theta));
        }));
    }

    println!("{}", render_table("varinfo micro-benchmarks (per call)", &rows));

    // the headline ratio
    let find = |n: &str| rows.iter().find(|m| m.name == n).map(|m| m.mean()).unwrap();
    for name in ["gauss_unknown", "logreg", "sto_volatility", "lda"] {
        let u = find(&format!("{name}/logp untyped"));
        let t = find(&format!("{name}/logp typed"));
        println!("{name}: untyped/typed = {:.2}×", u / t);
    }
}
