//! `cargo bench --bench table1` — regenerates the paper's Table 1.
//!
//! Every benchmark model × every backend, static HMC with 4 leapfrog steps
//! (the paper's configuration). Honest full-length runs for the fast
//! backends; the deliberately-slow dynamic paths are extrapolated from
//! shorter runs (marked `~`), preserving the ordering/ratio claims.
//!
//! Env knobs:
//!   T1_ITERS   target iteration count (default 2000, the paper's value)
//!   T1_REPS    replicates per cell (default 3)
//!   T1_MODELS  comma-separated subset
//!   T1_FULL=1  disable extrapolation (run slow paths in full)

use dynamicppl::bench::{render_table1, run_table1, Table1Config};

fn main() {
    let mut cfg = Table1Config::default();
    if let Ok(v) = std::env::var("T1_ITERS") {
        cfg.iters = v.parse().expect("T1_ITERS");
    }
    if let Ok(v) = std::env::var("T1_REPS") {
        cfg.reps = v.parse().expect("T1_REPS");
    }
    if let Ok(v) = std::env::var("T1_MODELS") {
        cfg.models = v.split(',').map(|s| s.trim().to_string()).collect();
    }
    if let Ok(v) = std::env::var("T1_MAX_RUN") {
        cfg.max_run_iters = Some(v.parse().expect("T1_MAX_RUN"));
    }
    if std::env::var("T1_FUSED").is_ok() {
        cfg.backends.push(dynamicppl::bench::BenchBackend::TypedXlaFused);
    }
    if std::env::var("T1_FULL").is_ok() {
        cfg.max_run_iters = None;
    }
    let cells = run_table1(&cfg);
    println!("{}", render_table1(&cells, &cfg));
}
