//! Property-style coverage over the benchmark suite: all four gradient
//! engines must agree on every model at randomized points (the coordinator
//! invariants: routing a model through any backend yields the same
//! density), and the CLI surface must hold together.

use dynamicppl::context::Context;
use dynamicppl::coordinator;
use dynamicppl::gradient::LogDensity;
use dynamicppl::model::{
    init_trace, init_typed, typed_grad_reverse, typed_logp, untyped_grad_reverse,
};
use dynamicppl::models::{build_small, ALL_MODELS};
use dynamicppl::stanlike::stanlike_density;
use dynamicppl::util::rng::{Rng, Xoshiro256pp};
use dynamicppl::varinfo::TypedVarInfo;

/// Randomized cross-backend agreement: for every model, at 5 random
/// unconstrained points, typed, untyped and stanlike paths agree on logp
/// and gradient. (Our hand-rolled property-test loop: seeded generation,
/// shrink-free but reproducible.)
#[test]
fn property_all_backends_agree_everywhere() {
    let mut gen = Xoshiro256pp::seed_from_u64(777);
    for name in ALL_MODELS {
        let bm = build_small(name, 21);
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let vi = init_trace(bm.model.as_ref(), &mut rng);
        let tvi = TypedVarInfo::from_untyped(&vi);
        let stan = stanlike_density(&bm);
        for trial in 0..5 {
            let theta: Vec<f64> = (0..tvi.dim()).map(|_| gen.normal() * 0.4).collect();
            let lp_typed = typed_logp(bm.model.as_ref(), &tvi, &theta, Context::Default);
            let lp_untyped =
                dynamicppl::model::untyped_logp(bm.model.as_ref(), &vi, &theta, Context::Default);
            let lp_stan = stan.logp(&theta);
            let denom = 1.0 + lp_typed.abs();
            assert!(
                ((lp_typed - lp_untyped) / denom).abs() < 1e-10,
                "{name} trial {trial}: typed {lp_typed} vs untyped {lp_untyped}"
            );
            assert!(
                ((lp_typed - lp_stan) / denom).abs() < 1e-9,
                "{name} trial {trial}: typed {lp_typed} vs stanlike {lp_stan}"
            );
            // gradients: tape (typed & untyped) vs analytic
            let (_, g_t) = typed_grad_reverse(bm.model.as_ref(), &tvi, &theta, Context::Default);
            let (_, g_u) =
                untyped_grad_reverse(bm.model.as_ref(), &vi, &theta, Context::Default);
            let (_, g_s) = stan.logp_grad(&theta);
            for i in 0..theta.len() {
                let scale = 1.0 + g_s[i].abs();
                assert!(
                    ((g_t[i] - g_s[i]) / scale).abs() < 1e-7,
                    "{name} trial {trial} grad[{i}]: tape {} vs analytic {}",
                    g_t[i],
                    g_s[i]
                );
                assert!(
                    ((g_u[i] - g_t[i]) / scale).abs() < 1e-10,
                    "{name} trial {trial} grad[{i}]: untyped vs typed"
                );
            }
        }
    }
}

/// Trace-level invariant: specialize → perturb θ → constrained row stays
/// consistent with the domains (simplexes sum to 1, positives positive).
#[test]
fn property_constrained_rows_respect_domains() {
    let mut gen = Xoshiro256pp::seed_from_u64(99);
    for name in ALL_MODELS {
        let bm = build_small(name, 5);
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut tvi = init_typed(bm.model.as_ref(), &mut rng);
        for _ in 0..10 {
            let theta: Vec<f64> = (0..tvi.dim()).map(|_| gen.normal() * 2.0).collect();
            tvi.set_unconstrained(&theta);
            for slot in tvi.slots().to_vec() {
                use dynamicppl::dist::Domain;
                let lo = slot.cons_offset;
                let hi = lo + slot.cons_len;
                match slot.domain {
                    Domain::Simplex(_) => {
                        let s: f64 = tvi.constrained[lo..hi].iter().sum();
                        assert!((s - 1.0).abs() < 1e-10, "{name}: simplex sum {s}");
                        assert!(tvi.constrained[lo..hi].iter().all(|&v| v > 0.0));
                    }
                    Domain::Positive | Domain::PositiveVec(_) => {
                        assert!(tvi.constrained[lo..hi].iter().all(|&v| v > 0.0));
                    }
                    Domain::Interval(a, b) => {
                        assert!(tvi.constrained[lo..hi].iter().all(|&v| v > a && v < b));
                    }
                    _ => {}
                }
            }
        }
    }
}

#[test]
fn coordinator_cli_surface() {
    // `list`/`info` exercise the registry + runtime without sampling.
    assert_eq!(coordinator::run(vec!["list".into()]), 0);
    // sample with a bad model errors cleanly
    assert_eq!(
        coordinator::run(vec![
            "sample".into(),
            "--model".into(),
            "not_a_model".into()
        ]),
        1
    );
    // bad sampler
    let err = coordinator::sample_model("hier_poisson", "warp", "stan", 1, 1, 1, 0, None);
    assert!(err.is_err());
}
