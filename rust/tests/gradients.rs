//! Gradient-correctness cross-validation for the arena-fused engine
//! (`Backend::ReverseFused`): on every `stanlike` benchmark model and on a
//! "distribution zoo" covering all built-in distributions (through linked
//! / unconstrained parameterizations and their bijector Jacobians), the
//! fused gradient must agree with forward duals to 1e-8 relative error and
//! with central finite differences to FD accuracy — plus structural
//! checks: fewer tape nodes than the per-op tape, zero steady-state arena
//! allocation, and correct −∞ handling.

use dynamicppl::ad::{arena, finite_diff_grad, reverse};
use dynamicppl::context::Context;
use dynamicppl::gradient::{Backend, LogDensity, NativeDensity};
use dynamicppl::model::{
    init_trace, init_typed, typed_grad_forward, typed_grad_fused, typed_grad_fused_into,
    typed_grad_reverse, typed_logp, untyped_grad_fused,
};
use dynamicppl::models::{build_small, ALL_MODELS};
use dynamicppl::prelude::*;
use dynamicppl::varinfo::TypedVarInfo;

/// A mildly-perturbed, numerically safe evaluation point (same recipe as
/// the stanlike consistency test).
fn test_point(dim: usize) -> Vec<f64> {
    (0..dim).map(|i| 0.07 * ((i % 11) as f64) - 0.3).collect()
}

fn assert_close(name: &str, got: &[f64], want: &[f64], rel: f64) {
    assert_eq!(got.len(), want.len(), "{name}: gradient length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let scale = 1.0 + b.abs();
        assert!(
            ((a - b) / scale).abs() < rel,
            "{name} grad[{i}]: {a} vs {b}"
        );
    }
}

/// Acceptance criterion: `ReverseFused` is bitwise-finite and within 1e-8
/// relative error of `Forward` on every benchmark model, and matches
/// central finite differences.
#[test]
fn fused_matches_forward_and_fd_on_all_models() {
    for name in ALL_MODELS {
        let bm = build_small(name, 17);
        let mut rng = Xoshiro256pp::seed_from_u64(17);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta = test_point(tvi.dim());

        let (lp_fused, g_fused) =
            typed_grad_fused(bm.model.as_ref(), &tvi, &theta, Context::Default);
        let (lp_fwd, g_fwd) =
            typed_grad_forward(bm.model.as_ref(), &tvi, &theta, Context::Default);

        assert!(lp_fused.is_finite(), "{name}: fused logp {lp_fused}");
        assert!(g_fused.iter().all(|g| g.is_finite()), "{name}: non-finite grad");
        let denom = 1.0 + lp_fwd.abs();
        assert!(
            ((lp_fused - lp_fwd) / denom).abs() < 1e-10,
            "{name}: logp fused {lp_fused} vs forward {lp_fwd}"
        );
        assert_close(name, &g_fused, &g_fwd, 1e-8);

        // FD oracle (looser: FD truncation error)
        let fd = finite_diff_grad(
            |t| typed_logp(bm.model.as_ref(), &tvi, t, Context::Default),
            &theta,
            1e-6,
        );
        assert_close(&format!("{name} (fd)"), &g_fused, &fd, 1e-4);
    }
}

/// The boxed-trace fused path must agree with the typed fused path (same
/// kernels, different addressing).
#[test]
fn untyped_fused_matches_typed_fused() {
    for name in ["gauss_unknown", "sto_volatility", "hier_poisson", "lda"] {
        let bm = build_small(name, 23);
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let vi = init_trace(bm.model.as_ref(), &mut rng);
        let tvi = TypedVarInfo::from_untyped(&vi);
        let theta = test_point(tvi.dim());
        let (lp_t, g_t) = typed_grad_fused(bm.model.as_ref(), &tvi, &theta, Context::Default);
        let (lp_u, g_u) = untyped_grad_fused(bm.model.as_ref(), &vi, &theta, Context::Default);
        assert!((lp_t - lp_u).abs() < 1e-12, "{name}: {lp_t} vs {lp_u}");
        assert_close(name, &g_u, &g_t, 1e-12);
    }
}

model! {
    /// Distribution zoo: every built-in distribution behind every bijector
    /// family, with parameters *linked through earlier parameters* so the
    /// fused kernels' parameter partials and the bijector Jacobians are
    /// all load-bearing. Discrete latents enter through their (AD-tracked)
    /// parameters; discrete observations cover the remaining pmfs.
    pub DistZoo {
        y: Vec<f64>,
        counts: Vec<i64>,
        flags: Vec<i64>,
    }
    fn body<T>(this, api) {
        // scalar continuous, chained: each prior's parameters depend on
        // earlier draws
        let sigma = tilde!(api, sigma ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        let rate = tilde!(api, rate ~ Gamma(c(2.0), sigma));
        let lam = tilde!(api, lam ~ Exponential(rate));
        let p = tilde!(api, p ~ Beta(rate, c(2.0)));
        let u = tilde!(api, u ~ Uniform(c(-1.0), c(1.0)));
        let loc = tilde!(api, loc ~ Cauchy(u, sigma));
        let hc = tilde!(api, hc ~ HalfCauchy(sigma));
        let m = tilde!(api, m ~ Normal(loc, hc.sqrt()));
        check_reject!(api);

        // vector continuous: identity and stick-breaking transforms
        let w = tilde_vec!(api, w ~ IsoNormal(m, sigma.sqrt(), 3));
        let th = tilde_vec!(api, th ~ Dirichlet(vec![2.0, 0.5, 1.0, 1.5]));
        check_reject!(api);

        // discrete latents: pmf parameters carry gradients (Categorical
        // has no `new`, so it goes through the api directly)
        let z = tilde_int!(api, z ~ Bernoulli(p));
        let cat: DiscreteDist<T> =
            DiscreteDist::Categorical(Categorical::from_probs(&[0.2, 0.3, 0.5]));
        let k = api.assume_int(VarName::new("kq"), &cat);

        // observations exercising every observe form
        let mu = m + w[0] * 0.5 + th[(k as usize) % 3] + z as f64;
        for &yi in &this.y {
            obs!(api, yi => Normal(mu, hc + 0.1));
        }
        obs_vec!(api, &this.y[..3] => IsoNormal(mu, sigma, 3));
        for &c_ in &this.counts {
            obs_int!(api, c_ => Poisson(lam + 0.5));
        }
        for &f_ in &this.flags {
            obs_int!(api, f_ => BernoulliLogit(m - lam));
        }
        obs_int!(api, 1 => Bernoulli(p));
        let cat_obs: DiscreteDist<T> =
            DiscreteDist::Categorical(Categorical::from_probs(&[0.3, 0.3, 0.4]));
        api.observe_int(&cat_obs, k);
        // raw-term escape hatch: body-op tape feeding a seed
        api.add_obs_logp(-(m - loc) * (m - loc) * 0.5);
    }
}

fn zoo() -> DistZoo {
    DistZoo {
        y: vec![0.4, -0.3, 1.1, 0.0],
        counts: vec![0, 2, 5],
        flags: vec![1, 0, 1],
    }
}

/// All 14 distributions (8 scalar, 2 vector, 4 discrete) through their
/// linked parameterizations: fused vs forward duals vs finite differences.
#[test]
fn dist_zoo_linked_gradients_agree() {
    let m = zoo();
    let mut rng = Xoshiro256pp::seed_from_u64(41);
    let tvi = init_typed(&m, &mut rng);
    // domains covered: Positive ×4, Interval ×2, Real ×2, RealVec, Simplex
    let theta = test_point(tvi.dim());

    let (lp_fused, g_fused) = typed_grad_fused(&m, &tvi, &theta, Context::Default);
    let (lp_fwd, g_fwd) = typed_grad_forward(&m, &tvi, &theta, Context::Default);
    assert!(lp_fused.is_finite());
    assert!(((lp_fused - lp_fwd) / (1.0 + lp_fwd.abs())).abs() < 1e-10);
    assert_close("zoo fused-vs-forward", &g_fused, &g_fwd, 1e-8);

    let (lp_tape, g_tape) = typed_grad_reverse(&m, &tvi, &theta, Context::Default);
    assert!(((lp_fused - lp_tape) / (1.0 + lp_tape.abs())).abs() < 1e-10);
    assert_close("zoo fused-vs-tape", &g_fused, &g_tape, 1e-8);

    let fd = finite_diff_grad(|t| typed_logp(&m, &tvi, t, Context::Default), &theta, 1e-6);
    assert_close("zoo fused-vs-fd", &g_fused, &fd, 1e-4);

    // every unconstrained coordinate must actually receive gradient
    // (all Jacobians/parameter partials load-bearing)
    for (i, g) in g_fused.iter().enumerate() {
        assert!(g.abs() > 0.0, "dead coordinate {i}");
    }
}

/// Context weights flow through the fused seeds: likelihood-only and
/// minibatch-scaled gradients must match the forward engine too.
#[test]
fn dist_zoo_contexts_agree() {
    let m = zoo();
    let mut rng = Xoshiro256pp::seed_from_u64(43);
    let tvi = init_typed(&m, &mut rng);
    let theta = test_point(tvi.dim());
    for ctx in [
        Context::Likelihood,
        Context::Prior,
        Context::MiniBatch { scale: 7.5 },
    ] {
        let (lp_fused, g_fused) = typed_grad_fused(&m, &tvi, &theta, ctx);
        let (lp_fwd, g_fwd) = typed_grad_forward(&m, &tvi, &theta, ctx);
        assert!(
            ((lp_fused - lp_fwd) / (1.0 + lp_fwd.abs())).abs() < 1e-10,
            "{ctx:?}: {lp_fused} vs {lp_fwd}"
        );
        assert_close(&format!("{ctx:?}"), &g_fused, &g_fwd, 1e-8);
    }
}

/// Structural claims: one fused value-node per tilde at most (observes are
/// free), far fewer nodes than the per-op tape on tilde-dominated models,
/// and a bit-stable arena across repeated evaluations.
#[test]
fn fused_tape_is_small_and_allocation_free() {
    let bm = build_small("sto_volatility", 7);
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let theta = test_point(tvi.dim());
    let mut grad = vec![0.0; theta.len()];

    let _ = typed_grad_fused_into(bm.model.as_ref(), &tvi, &theta, Context::Default, &mut grad);
    let stats = arena::last_stats();
    let _ = typed_grad_reverse(bm.model.as_ref(), &tvi, &theta, Context::Default);
    let tape_nodes = reverse::last_tape_len();
    // sto_vol small: 54 tildes (4 scalar priors + 50 h's) + 50 observes;
    // the fused tape must be dominated by body ops, not density ops
    assert!(stats.tilde_stmts >= 100, "{}", stats.tilde_stmts);
    assert!(
        stats.nodes < tape_nodes / 4,
        "fused {} vs tape {} nodes",
        stats.nodes,
        tape_nodes
    );
    assert!(stats.seeds > 0);

    // zero steady-state allocation
    let cap = arena::capacity_bytes();
    for _ in 0..8 {
        let _ =
            typed_grad_fused_into(bm.model.as_ref(), &tvi, &theta, Context::Default, &mut grad);
    }
    assert_eq!(arena::capacity_bytes(), cap, "arena grew at steady state");
}

/// `logp_grad_into` through the `LogDensity` trait object (the sampler
/// view) must match `logp_grad`, for fused and non-fused backends.
#[test]
fn logp_grad_into_matches_logp_grad() {
    let bm = build_small("hier_poisson", 11);
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let theta = test_point(tvi.dim());
    for backend in [Backend::ReverseFused, Backend::Reverse, Backend::Forward] {
        let ld = NativeDensity::new(bm.model.as_ref(), &tvi, backend);
        let ld: &dyn LogDensity = &ld;
        let (lp, g) = ld.logp_grad(&theta);
        let mut g2 = vec![0.0; theta.len()];
        let lp2 = ld.logp_grad_into(&theta, &mut g2);
        assert_eq!(lp.to_bits(), lp2.to_bits(), "{backend:?}");
        for (a, b) in g.iter().zip(&g2) {
            assert_eq!(a.to_bits(), b.to_bits(), "{backend:?}");
        }
        assert!(lp.is_finite());
    }
}

/// Rejection semantics: a −∞ density must come back as −∞ with a zeroed
/// gradient buffer (HMC treats it as a divergence), not NaNs.
#[test]
fn fused_rejection_zeroes_gradient() {
    model! {
        pub RejectDemo { dummy: f64, }
        fn body<T>(this, api) {
            let _ = this.dummy;
            let x = tilde!(api, x ~ Normal(c(0.0), c(1.0)));
            // manual support constraint: reject half the space
            if x.value() < 0.0 {
                api.reject();
                return;
            }
            obs!(api, 0.5 => Normal(x, c(1.0)));
        }
    }
    let m = RejectDemo { dummy: 0.0 };
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    // seed the trace at an accepted point so the layout exists
    let tvi = loop {
        let mut vi = UntypedVarInfo::new();
        let _ = sample_run(&m, &mut rng, &mut vi, Context::Default);
        if vi.logp.is_finite() {
            break TypedVarInfo::from_untyped(&vi);
        }
    };
    let mut grad = vec![42.0; 1];
    let lp = typed_grad_fused_into(&m, &tvi, &[-0.7], Context::Default, &mut grad);
    assert_eq!(lp, f64::NEG_INFINITY);
    assert_eq!(grad, vec![0.0]);
    // and a finite point still works after the rejected run
    let lp = typed_grad_fused_into(&m, &tvi, &[0.7], Context::Default, &mut grad);
    assert!(lp.is_finite());
    assert!(grad[0].is_finite());
}

/// End-to-end: HMC over the fused backend samples the same posterior as
/// the hand-coded Stan-like density.
#[test]
fn hmc_fused_recovers_gauss_posterior() {
    use dynamicppl::inference::{sample_chain, Hmc, SamplerKind};
    use dynamicppl::util::stats;
    let bm = dynamicppl::models::gauss::gauss_unknown_n(1, 500);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let hmc = Hmc {
        step_size: bm.step_size,
        init_step_size: true, // warmup adapter probes ε via logp_grad_into
        ..Hmc::default()
    };
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Hmc(hmc), 800, 3000, 5);
    let m = chain.column("m").unwrap();
    let s = chain.column("s").unwrap();
    assert!((stats::mean(&m) - 1.5).abs() < 0.1, "{}", stats::mean(&m));
    assert!((stats::mean(&s) - 0.49).abs() < 0.1, "{}", stats::mean(&s));
    assert!(chain.stats.accept_rate > 0.5);
}
