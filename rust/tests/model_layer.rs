//! Integration tests across the model DSL, traces, contexts and executors:
//! a linear-regression model defined with `model!` must produce identical
//! log-densities through every execution path, and gradients must agree
//! between forward duals, the reverse tape and finite differences.

use dynamicppl::ad::finite_diff_grad;
use dynamicppl::prelude::*;

model! {
    /// Bayesian linear regression (the paper's first example model):
    /// s ~ InverseGamma(2,3); w ~ Normal(0, √s) per coordinate;
    /// y[i] ~ Normal(x[i]·w, √s).
    pub LinReg {
        x: Vec<Vec<f64>>,
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let s = tilde!(api, s ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        let sd = s.sqrt();
        let d = this.x[0].len();
        let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), sd, d));
        check_reject!(api);
        for i in 0..this.y.len() {
            let mut mu = c::<T>(0.0);
            for j in 0..d {
                mu = mu + w[j] * this.x[i][j];
            }
            obs!(api, this.y[i] => Normal(mu, sd));
        }
    }
}

fn demo_model() -> LinReg {
    LinReg {
        x: vec![
            vec![1.0, 0.5],
            vec![-0.3, 1.2],
            vec![0.8, -1.0],
            vec![2.0, 0.1],
        ],
        y: vec![1.1, 0.2, -0.4, 2.2],
    }
}

/// Reference log-joint computed by hand in constrained space.
fn manual_logp(m: &LinReg, s: f64, w: &[f64]) -> f64 {
    let mut lp = InverseGamma::new(2.0, 3.0).logpdf(s);
    lp += IsoNormal::new(0.0, s.sqrt(), 2).logpdf(w);
    for (xi, &yi) in m.x.iter().zip(&m.y) {
        let mu = w[0] * xi[0] + w[1] * xi[1];
        lp += Normal::new(mu, s.sqrt()).logpdf(yi);
    }
    lp
}

#[test]
fn init_trace_discovers_structure() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let vi = init_trace(&m, &mut rng);
    assert_eq!(vi.len(), 2);
    assert!(vi.contains(&VarName::new("s")));
    assert!(vi.contains(&VarName::new("w")));
    // s positive, w: R^2 → 3 unconstrained dims
    assert_eq!(vi.num_unconstrained(), 3);
    let s = vi.get(&VarName::new("s")).unwrap().value.as_f64().unwrap();
    assert!(s > 0.0);
}

#[test]
fn sample_run_logp_matches_manual() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let mut vi = UntypedVarInfo::new();
    let lp = sample_run(&m, &mut rng, &mut vi, Context::Default);
    let s = vi.get(&VarName::new("s")).unwrap().value.as_f64().unwrap();
    let w = vi
        .get(&VarName::new("w"))
        .unwrap()
        .value
        .as_slice()
        .unwrap()
        .to_vec();
    assert!((lp - manual_logp(&m, s, &w)).abs() < 1e-12);
}

#[test]
fn typed_and_untyped_paths_agree() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let vi = init_trace(&m, &mut rng);
    let tvi = TypedVarInfo::from_untyped(&vi);
    let theta = vi.to_unconstrained();
    assert_eq!(theta, tvi.unconstrained);
    for delta in [0.0, 0.5, -1.3] {
        let th: Vec<f64> = theta.iter().map(|t| t + delta).collect();
        let lp_typed = typed_logp(&m, &tvi, &th, Context::Default);
        let lp_untyped = untyped_logp(&m, &vi, &th, Context::Default);
        assert!(
            (lp_typed - lp_untyped).abs() < 1e-12,
            "typed {lp_typed} vs untyped {lp_untyped} at delta {delta}"
        );
    }
}

#[test]
fn typed_logp_matches_manual_plus_jacobian() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let tvi = init_typed(&m, &mut rng);
    let theta = tvi.unconstrained.clone();
    // manual: logp(constrained) + log|J| where only s is transformed
    // (s = exp(θ₀) ⇒ ladj = θ₀)
    let s = theta[0].exp();
    let w = [theta[1], theta[2]];
    let expect = manual_logp(&m, s, &w) + theta[0];
    let got = typed_logp(&m, &tvi, &theta, Context::Default);
    assert!((got - expect).abs() < 1e-12, "{got} vs {expect}");
}

#[test]
fn gradients_agree_across_backends() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let vi = init_trace(&m, &mut rng);
    let tvi = TypedVarInfo::from_untyped(&vi);
    let theta = vec![0.3, 0.7, -0.2];

    let (v_fwd, g_fwd) = typed_grad_forward(&m, &tvi, &theta, Context::Default);
    let (v_rev, g_rev) = typed_grad_reverse(&m, &tvi, &theta, Context::Default);
    let (v_ufwd, g_ufwd) = untyped_grad_forward(&m, &vi, &theta, Context::Default);
    let (v_urev, g_urev) = untyped_grad_reverse(&m, &vi, &theta, Context::Default);
    let fd = finite_diff_grad(
        |th| typed_logp(&m, &tvi, th, Context::Default),
        &theta,
        1e-6,
    );

    assert!((v_fwd - v_rev).abs() < 1e-12);
    assert!((v_fwd - v_ufwd).abs() < 1e-12);
    assert!((v_fwd - v_urev).abs() < 1e-12);
    for i in 0..theta.len() {
        assert!((g_fwd[i] - fd[i]).abs() < 1e-5, "fwd[{i}]");
        assert!((g_rev[i] - fd[i]).abs() < 1e-5, "rev[{i}]");
        assert!((g_ufwd[i] - fd[i]).abs() < 1e-5, "ufwd[{i}]");
        assert!((g_urev[i] - fd[i]).abs() < 1e-5, "urev[{i}]");
    }
}

#[test]
fn contexts_partition_the_log_joint() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let tvi = init_typed(&m, &mut rng);
    let theta = vec![0.1, -0.5, 0.9];
    let joint = typed_logp(&m, &tvi, &theta, Context::Default);
    let prior = typed_logp(&m, &tvi, &theta, Context::Prior);
    let lik = typed_logp(&m, &tvi, &theta, Context::Likelihood);
    assert!((joint - (prior + lik)).abs() < 1e-12);
    // MiniBatch with scale 1 == Default
    let mb1 = typed_logp(&m, &tvi, &theta, Context::MiniBatch { scale: 1.0 });
    assert!((mb1 - joint).abs() < 1e-12);
    // MiniBatch scale 3 scales only the likelihood part
    let mb3 = typed_logp(&m, &tvi, &theta, Context::MiniBatch { scale: 3.0 });
    assert!((mb3 - (prior + 3.0 * lik)).abs() < 1e-12);
}

#[test]
fn minibatch_context_is_unbiased_over_batches() {
    // Scaled minibatch likelihoods must average to the full-data likelihood.
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let tvi = init_typed(&m, &mut rng);
    let theta = vec![0.1, -0.5, 0.9];
    let full_lik = typed_logp(&m, &tvi, &theta, Context::Likelihood);
    // two half batches, each scaled ×2
    let m1 = LinReg {
        x: m.x[..2].to_vec(),
        y: m.y[..2].to_vec(),
    };
    let m2 = LinReg {
        x: m.x[2..].to_vec(),
        y: m.y[2..].to_vec(),
    };
    // same parameter trace works: identical parameter structure
    let lik1 = typed_logp(&m1, &tvi, &theta, Context::Likelihood);
    let lik2 = typed_logp(&m2, &tvi, &theta, Context::Likelihood);
    assert!((full_lik - (lik1 + lik2)).abs() < 1e-12);
    let mb1 = typed_logp(&m1, &tvi, &theta, Context::MiniBatch { scale: 2.0 })
        - typed_logp(&m1, &tvi, &theta, Context::Prior);
    let mb2 = typed_logp(&m2, &tvi, &theta, Context::MiniBatch { scale: 2.0 })
        - typed_logp(&m2, &tvi, &theta, Context::Prior);
    assert!(((mb1 + mb2) / 2.0 - full_lik).abs() < 1e-12);
}

model! {
    /// A model that rejects when its parameter is in a "bad" region —
    /// exercises early rejection (§3.3).
    pub Rejecting {
        threshold: f64,
    }
    fn body<T>(this, api) {
        let x = tilde!(api, x ~ Normal(c(0.0), c(1.0)));
        if x.value() > this.threshold {
            api.reject();
            return;
        }
        obs!(api, 1.0 => Normal(x, c(1.0)));
    }
}

#[test]
fn early_rejection_pins_neg_inf() {
    let m = Rejecting { threshold: 0.0 };
    let mut rng = Xoshiro256pp::seed_from_u64(8);
    let tvi = init_typed(&m, &mut rng);
    // θ > 0 rejects, θ < 0 doesn't
    let lp_bad = typed_logp(&m, &tvi, &[1.0], Context::Default);
    assert_eq!(lp_bad, f64::NEG_INFINITY);
    let lp_ok = typed_logp(&m, &tvi, &[-1.0], Context::Default);
    assert!(lp_ok.is_finite());
}

model! {
    /// A *dynamic* model: the number of traced variables depends on a
    /// parameter's value (the paper's "dynamic model dimensionality").
    pub DynamicDim {
        max_k: usize,
    }
    fn body<T>(this, api) {
        let r = tilde!(api, r ~ Beta(c(2.0), c(2.0)));
        // number of components grows with r
        let k = 1 + (r.value() * this.max_k as f64) as usize;
        for i in 0..k {
            let _ = tilde!(api, z[i] ~ Normal(c(0.0), c(1.0)));
        }
    }
}

#[test]
fn dynamic_model_changes_structure_and_layout_detects_it() {
    let m = DynamicDim { max_k: 6 };
    // find two seeds giving different k
    let mut dims = std::collections::HashSet::new();
    let mut traces = Vec::new();
    for seed in 0..20 {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let vi = init_trace(&m, &mut rng);
        dims.insert(vi.len());
        traces.push(vi);
    }
    assert!(dims.len() > 1, "expected varying structure, got {dims:?}");
    // layout from one structure must reject a different structure
    let t0 = TypedVarInfo::from_untyped(&traces[0]);
    let other = traces
        .iter()
        .find(|v| v.len() != traces[0].len())
        .expect("some trace differs");
    assert!(!t0.layout_matches(other));
    assert!(t0.layout_matches(&traces[0]));
}

#[test]
fn resample_flag_forces_fresh_draws() {
    let m = demo_model();
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let mut vi = init_trace(&m, &mut rng);
    let s0 = vi.get(&VarName::new("s")).unwrap().value.clone();
    // without flag: value kept
    let _ = sample_run(&m, &mut rng, &mut vi, Context::Default);
    assert_eq!(vi.get(&VarName::new("s")).unwrap().value, s0);
    // with flag: value redrawn
    vi.flag_all_resample();
    let _ = sample_run(&m, &mut rng, &mut vi, Context::Default);
    assert_ne!(vi.get(&VarName::new("s")).unwrap().value, s0);
}

model! {
    /// Missing-data promotion (paper §2.1: "RVs … given a value of
    /// `missing` will be treated as model parameters"): observations are
    /// `Option<f64>`; `None` entries become latent variables.
    pub MissingData {
        y: Vec<Option<f64>>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(10.0)));
        for (i, yi) in this.y.iter().enumerate() {
            match yi {
                Some(v) => obs!(api, *v => Normal(m, c(1.0))),
                // missing observation → promoted to a parameter
                None => {
                    let _ = tilde!(api, y_miss[i] ~ Normal(m, c(1.0)));
                }
            }
        }
    }
}

#[test]
fn missing_data_becomes_parameter() {
    let m = MissingData {
        y: vec![Some(1.0), None, Some(2.0), None],
    };
    let mut rng = Xoshiro256pp::seed_from_u64(14);
    let vi = init_trace(&m, &mut rng);
    // parameters: m + 2 promoted missing observations
    assert_eq!(vi.len(), 3);
    assert!(vi.contains(&VarName::indexed("y_miss", 1)));
    assert!(vi.contains(&VarName::indexed("y_miss", 3)));
    assert!(!vi.contains(&VarName::indexed("y_miss", 0)));
    let tvi = TypedVarInfo::from_untyped(&vi);
    assert_eq!(tvi.dim(), 3);
    // and the posterior over a missing point tracks the mean parameter
    use dynamicppl::gradient::{Backend, NativeDensity};
    use dynamicppl::inference::{sample_chain, Nuts, SamplerKind};
    let ld = NativeDensity::new(&m, &tvi, Backend::Reverse);
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Nuts(Nuts::default()), 500, 2000, 2);
    let mm = chain.mean("m").unwrap();
    let y1 = chain.mean("y_miss[1]").unwrap();
    assert!((mm - 1.5).abs() < 0.6, "m posterior {mm}");
    assert!((y1 - mm).abs() < 0.4, "missing-data posterior should track m");
}
