//! Acceptance tests for the static-analysis subsystem: conjugacy
//! certificates on all five supported families (and their refusal on
//! non-affine glue), Rao-Blackwellized Gibbs against the closed-form
//! Normal–InverseGamma posterior with bitwise determinism, collapsed SMC
//! evidence against the sequential conjugate oracle, and the pedantic
//! lint pass over both the seeded-defect fixture and the full model zoo.

use dynamicppl::analysis::{analyze, lint_model, ConjugateFamily};
use dynamicppl::bench::{run_conjugate_bench, ConjugateBenchConfig};
use dynamicppl::inference::{Gibbs, GibbsBlock, Smc};
use dynamicppl::models::{build_small, ALL_MODELS, EXTRA_MODELS};
use dynamicppl::runtime::DataInput;
use dynamicppl::prelude::*;

// ------------------------------------------------------------- models
//
// One tiny model per conjugate family (positive cases), plus one per
// unsupported-glue shape (negative cases). Data is baked in by the test.

model! {
    /// Identity Normal–Normal: `m ~ N(0,1); y_i ~ N(m, 1)`.
    pub NormalNormal {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m, c(1.0)));
        }
    }
}

model! {
    /// Normal–Normal through affine glue: `y_i ~ N(2m + 0.5, 1.5)`.
    pub NnAffine {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m * 2.0 + 0.5, c(1.5)));
        }
    }
}

model! {
    /// Normal–InverseGamma: `v ~ IG(2,3); y_i ~ N(0, sqrt(3v))`.
    pub NigScale {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let v = tilde!(api, v ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        for &yi in &this.y {
            obs!(api, yi => Normal(c(0.0), (v * 3.0).sqrt()));
        }
    }
}

model! {
    /// Gamma–Poisson with a pure scale: `r ~ Gamma(2,1); k_i ~ Poisson(3r)`.
    pub GammaPois {
        k: Vec<i64>,
    }
    fn body<T>(this, api) {
        let r = tilde!(api, r ~ Gamma(c(2.0), c(1.0)));
        check_reject!(api);
        for &ki in &this.k {
            obs_int!(api, ki => Poisson(r * 3.0));
        }
    }
}

model! {
    /// Beta–Bernoulli through identity glue: `p ~ Beta(1,1); z_i ~ Bern(p)`.
    pub BetaBern {
        z: Vec<i64>,
    }
    fn body<T>(this, api) {
        let p = tilde!(api, p ~ Beta(c(1.0), c(1.0)));
        check_reject!(api);
        for &zi in &this.z {
            obs_int!(api, zi => Bernoulli(p));
        }
    }
}

model! {
    /// Dirichlet–Categorical: `w ~ Dir(1,1,1); z_i ~ Cat(w)` written as
    /// explicit `ln w[z_i]` observation terms.
    pub DirCat {
        z: Vec<i64>,
    }
    fn body<T>(this, api) {
        let w = tilde_vec!(api, w ~ Dirichlet(vec![1.0; 3]));
        for &zi in &this.z {
            api.add_obs_logp(w[zi as usize].ln());
        }
    }
}

model! {
    /// Quadratic mean glue — NOT affine, must never certify.
    pub NnSquared {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m * m, c(1.0)));
        }
    }
}

model! {
    /// Exponential mean glue — NOT affine, must never certify.
    pub NnExp {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m.exp(), c(1.0)));
        }
    }
}

model! {
    /// IG variance fed *linearly* into the sd slot (not `sqrt(a·v)`) —
    /// wrong shape for Normal–InverseGamma, must never certify.
    pub IgLinearSd {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let v = tilde!(api, v ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        for &yi in &this.y {
            obs!(api, yi => Normal(c(0.0), v));
        }
    }
}

model! {
    /// Shifted Poisson rate `r + 1` — affine but not a pure scale, must
    /// never certify as Gamma–Poisson.
    pub PoisShifted {
        k: Vec<i64>,
    }
    fn body<T>(this, api) {
        let r = tilde!(api, r ~ Gamma(c(2.0), c(1.0)));
        check_reject!(api);
        for &ki in &this.k {
            obs_int!(api, ki => Poisson(r + 1.0));
        }
    }
}

model! {
    /// Scaled Bernoulli probability `p/2` — not identity glue, must never
    /// certify as Beta–Bernoulli.
    pub BernScaled {
        z: Vec<i64>,
    }
    fn body<T>(this, api) {
        let p = tilde!(api, p ~ Beta(c(1.0), c(1.0)));
        check_reject!(api);
        for &zi in &this.z {
            obs_int!(api, zi => Bernoulli(p * 0.5));
        }
    }
}

model! {
    /// Dirichlet component used outside `ln w[k]` — must never certify.
    pub DirMul {
        z: Vec<i64>,
    }
    fn body<T>(this, api) {
        let w = tilde_vec!(api, w ~ Dirichlet(vec![1.0; 3]));
        for &zi in &this.z {
            api.add_obs_logp(w[zi as usize] * 0.5);
        }
    }
}

model! {
    /// A discrete latent anywhere in the model suppresses ALL certificates
    /// (a Gibbs flip of `g` could change the walk invisibly to the
    /// continuous perturbation gate), even though `m` alone would certify.
    pub DiscreteGated {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        let _g = tilde_int!(api, g ~ Bernoulli(c(0.5)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m, c(1.0)));
        }
    }
}

// ------------------------------------------------------------ helpers

fn tvi_for(model: &dyn Model, seed: u64) -> TypedVarInfo {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    init_typed(model, &mut rng)
}

/// Sequential conjugate log-evidence of the identity Normal–Normal model
/// (same oracle the SMC suite uses): `m ~ N(0,1); y_t ~ N(m,1)`.
fn conjugate_log_evidence(y: &[f64]) -> f64 {
    let (mut mu, mut tau2) = (0.0f64, 1.0f64);
    let mut lz = 0.0;
    for &yt in y {
        let pv = 1.0 + tau2;
        lz += Normal::new(mu, pv.sqrt()).logpdf(yt);
        let k = tau2 / pv;
        mu += k * (yt - mu);
        tau2 *= 1.0 - k;
    }
    lz
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

fn variance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

// ---------------------------------------------------- conjugacy: positive

#[test]
fn conjugacy_fires_on_all_five_families() {
    let y = vec![0.3, -1.2, 0.8, 2.1, -0.4, 1.5, 0.0, 0.9];
    let k: Vec<i64> = vec![2, 5, 1, 0, 3, 4, 2, 6];
    let z01: Vec<i64> = vec![1, 0, 1, 1, 0, 1, 0, 1];
    let zcat: Vec<i64> = vec![0, 2, 1, 1, 0, 2, 2, 1, 0];

    let cases: Vec<(Box<dyn Model>, &str, ConjugateFamily, usize)> = vec![
        (
            Box::new(NormalNormal { y: y.clone() }),
            "m",
            ConjugateFamily::NormalNormal,
            y.len(),
        ),
        (
            Box::new(NnAffine { y: y.clone() }),
            "m",
            ConjugateFamily::NormalNormal,
            y.len(),
        ),
        (
            Box::new(NigScale { y: y.clone() }),
            "v",
            ConjugateFamily::NormalInverseGamma,
            y.len(),
        ),
        (
            Box::new(GammaPois { k: k.clone() }),
            "r",
            ConjugateFamily::GammaPoisson,
            k.len(),
        ),
        (
            Box::new(BetaBern { z: z01.clone() }),
            "p",
            ConjugateFamily::BetaBernoulli,
            z01.len(),
        ),
        (
            Box::new(DirCat { z: zcat.clone() }),
            "w",
            ConjugateFamily::DirichletCategorical,
            zcat.len(),
        ),
    ];
    for (model, name, family, n_children) in cases {
        let tvi = tvi_for(model.as_ref(), 17);
        let a = analyze(model.as_ref(), &tvi)
            .unwrap_or_else(|| panic!("{name}: analysis refused a static model"));
        assert_eq!(a.certs.len(), 1, "{name}: expected exactly one certificate");
        let cert = &a.certs[0];
        assert_eq!(cert.name, name, "certificate names the parent site");
        assert_eq!(cert.family, family, "{name}: wrong family");
        assert_eq!(
            cert.n_children, n_children,
            "{name}: every observation row must be a recognized child"
        );
    }
}

// ---------------------------------------------------- conjugacy: negative

#[test]
fn conjugacy_never_fires_on_unsupported_glue() {
    let y = vec![0.3, -1.2, 0.8, 2.1];
    let k: Vec<i64> = vec![2, 5, 1, 0];
    let z01: Vec<i64> = vec![1, 0, 1, 1];
    let zcat: Vec<i64> = vec![0, 2, 1, 1];

    let cases: Vec<(Box<dyn Model>, &str)> = vec![
        (Box::new(NnSquared { y: y.clone() }), "quadratic mean"),
        (Box::new(NnExp { y: y.clone() }), "exp mean"),
        (Box::new(IgLinearSd { y: y.clone() }), "linear sd"),
        (Box::new(PoisShifted { k: k.clone() }), "shifted rate"),
        (Box::new(BernScaled { z: z01.clone() }), "scaled probability"),
        (Box::new(DirMul { z: zcat.clone() }), "non-log simplex use"),
    ];
    for (model, what) in cases {
        let tvi = tvi_for(model.as_ref(), 23);
        let a = analyze(model.as_ref(), &tvi)
            .unwrap_or_else(|| panic!("{what}: analysis refused a static model"));
        assert!(
            a.certs.is_empty(),
            "{what}: a certificate was issued against unsupported glue"
        );
    }
}

#[test]
fn a_discrete_site_suppresses_all_certificates() {
    let model = DiscreteGated {
        y: vec![0.3, -1.2, 0.8, 2.1],
    };
    let tvi = tvi_for(&model, 29);
    let a = analyze(&model, &tvi).expect("static model must analyze");
    assert_eq!(a.graph.sites.len(), 2);
    assert!(
        a.certs.is_empty(),
        "no certificates may survive a discrete latent"
    );
}

// ------------------------------------------- collapsed Gibbs vs closed form

#[test]
fn collapsed_gibbs_matches_the_normal_inverse_gamma_posterior() {
    // conjugate_hier (small): v ~ IG(2,3); m|v ~ N(0, 2v); y_i ~ N(m, v),
    // i.e. a Normal–Inverse-Gamma prior with κ0 = 1/2, α0 = 2, β0 = 3.
    let bm = build_small("conjugate_hier", 7);
    let y = match &bm.data[0] {
        DataInput::F64 { data, .. } => data.clone(),
        _ => unreachable!(),
    };
    let n = y.len() as f64;
    let ybar = mean(&y);
    let ss: f64 = y.iter().map(|v| (v - ybar) * (v - ybar)).sum();
    let (k0, a0, b0) = (0.5f64, 2.0f64, 3.0f64);
    let kn = k0 + n;
    let mun = n * ybar / kn;
    let an = a0 + n / 2.0;
    let bn = b0 + 0.5 * ss + 0.5 * n * k0 * ybar * ybar / kn;
    let m_mean = mun;
    let m_var = bn / (kn * (an - 1.0));
    let v_mean = bn / (an - 1.0);
    let v_var = bn * bn / ((an - 1.0) * (an - 1.0) * (an - 2.0));

    let tvi = tvi_for(bm.model.as_ref(), 11);
    let a = analyze(bm.model.as_ref(), &tvi).expect("conjugate_hier must analyze");
    assert_eq!(a.certs.len(), 2, "both latents must certify");

    // Both blocks are nominally RwMh; collapse (the Gibbs::new default)
    // upgrades each to exact closed-form full-conditional draws.
    let gibbs = Gibbs::new(vec![
        GibbsBlock::rwmh(&["v"], 0.2),
        GibbsBlock::rwmh(&["m"], 0.2),
    ]);
    assert!(gibbs.collapse);
    let mut rng = Xoshiro256pp::seed_from_u64(12);
    let draws = gibbs.sample(bm.model.as_ref(), &tvi, 500, 40_000, &mut rng);
    assert_eq!(draws.rows.len(), 40_000);

    // row order = slot order = [v, m]
    let vs: Vec<f64> = draws.rows.iter().map(|r| r[0]).collect();
    let ms: Vec<f64> = draws.rows.iter().map(|r| r[1]).collect();
    let rel = |got: f64, want: f64| ((got - want) / want).abs();
    assert!(
        rel(mean(&ms), m_mean) < 0.02,
        "E[m]: got {} want {m_mean}",
        mean(&ms)
    );
    assert!(
        rel(variance(&ms), m_var) < 0.02,
        "Var[m]: got {} want {m_var}",
        variance(&ms)
    );
    assert!(
        rel(mean(&vs), v_mean) < 0.02,
        "E[v]: got {} want {v_mean}",
        mean(&vs)
    );
    assert!(
        rel(variance(&vs), v_var) < 0.02,
        "Var[v]: got {} want {v_var}",
        variance(&vs)
    );
}

#[test]
fn collapsed_gibbs_is_bitwise_deterministic_for_a_fixed_seed() {
    let bm = build_small("conjugate_hier", 3);
    let tvi = tvi_for(bm.model.as_ref(), 31);
    let gibbs = Gibbs::new(vec![
        GibbsBlock::rwmh(&["v"], 0.2),
        GibbsBlock::rwmh(&["m"], 0.2),
    ]);
    let run = || {
        let mut rng = Xoshiro256pp::seed_from_u64(37);
        gibbs.sample(bm.model.as_ref(), &tvi, 50, 400, &mut rng)
    };
    let (d1, d2) = (run(), run());
    assert_eq!(d1.rows.len(), d2.rows.len());
    for (r1, r2) in d1.rows.iter().zip(&d2.rows) {
        for (x1, x2) in r1.iter().zip(r2) {
            assert_eq!(x1.to_bits(), x2.to_bits(), "draws must be bitwise equal");
        }
    }
    for (l1, l2) in d1.logps.iter().zip(&d2.logps) {
        assert_eq!(l1.to_bits(), l2.to_bits(), "logps must be bitwise equal");
    }
}

// --------------------------------------------- collapsed SMC log-evidence

#[test]
fn collapsed_smc_recovers_the_exact_log_evidence() {
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let y: Vec<f64> = (0..40).map(|_| 0.5 + rng.normal()).collect();
    let want = conjugate_log_evidence(&y);
    let model = NormalNormal { y };
    let smc = Smc {
        n_particles: 256,
        use_collapsed: true,
        ..Smc::default()
    };
    let res = smc.run(&model, 99);
    assert!(
        (res.log_evidence - want).abs() < 1e-6,
        "collapsed log-evidence {} vs exact {want}",
        res.log_evidence
    );
    // The default (particle) estimate is noisy where the collapsed one is
    // exact — same run without the flag should still be in the vicinity.
    let res_mc = Smc {
        n_particles: 256,
        use_collapsed: false,
        ..Smc::default()
    }
    .run(&model, 99);
    assert!((res_mc.log_evidence - want).abs() < 2.0);
}

// --------------------------------------------------------------- linting

#[test]
fn lint_flags_every_seeded_defect_on_the_fixture() {
    let bm = build_small("lint_fixture", 42);
    let tvi = tvi_for(bm.model.as_ref(), 41);
    let report = lint_model(bm.model.as_ref(), &tvi).expect("fixture must lint");

    assert!(report.has_errors(), "the domain mismatch is an error");
    for code in [
        "domain-mismatch",
        "dead-parameter",
        "centered-funnel",
        "constant-data-plate",
    ] {
        assert!(report.has_code(code), "missing expected finding `{code}`");
    }
    let site_of = |code: &str| -> Vec<&str> {
        report
            .findings
            .iter()
            .filter(|f| f.code == code)
            .map(|f| f.site.as_str())
            .collect()
    };
    assert_eq!(site_of("dead-parameter"), ["unused"]);
    assert_eq!(site_of("domain-mismatch"), ["tau"]);
    assert_eq!(site_of("centered-funnel"), ["x"]);
    assert_eq!(report.n_errors(), 1);

    // machine-readable output survives our own parser
    let parsed = dynamicppl::util::json::Json::parse(&report.to_json()).expect("valid JSON");
    assert!(parsed.get("findings").is_some());
}

#[test]
fn zoo_models_lint_clean_of_errors_and_false_positives() {
    // Expected centered-funnel sites: the three genuinely centered
    // hierarchies in the zoo. Everything else must produce no funnel, no
    // dead parameters, and no errors at all. (constant-data-plate is not
    // asserted on: small synthetic count data can legitimately produce an
    // all-identical plate for some seeds.)
    let funnel_expect = |name: &str| -> Vec<&str> {
        match name {
            "gauss_unknown" => vec!["m"],
            "hier_poisson" => vec!["b"],
            "sto_volatility" => vec!["h"],
            _ => vec![],
        }
    };
    for name in ALL_MODELS.iter().chain(EXTRA_MODELS.iter()) {
        let bm = build_small(name, 42);
        let tvi = tvi_for(bm.model.as_ref(), 43);
        let report = lint_model(bm.model.as_ref(), &tvi)
            .unwrap_or_else(|| panic!("{name}: lint refused (rejected walk)"));
        assert_eq!(report.n_errors(), 0, "{name}: {}", report.render());
        assert!(
            !report.has_code("dead-parameter"),
            "{name}: false-positive dead parameter\n{}",
            report.render()
        );
        let funnels: Vec<&str> = report
            .findings
            .iter()
            .filter(|f| f.code == "centered-funnel")
            .map(|f| f.site.as_str())
            .collect();
        assert_eq!(
            funnels,
            funnel_expect(name),
            "{name}: centered-funnel mismatch\n{}",
            report.render()
        );
    }
}

// ------------------------------------------------------ bench smoke test

#[test]
fn conjugate_bench_runs_and_reports_certificates() {
    let cfg = ConjugateBenchConfig {
        models: vec!["conjugate_hier".to_string()],
        seed: 3,
        small: true,
        warmup: 100,
        iters: 400,
    };
    let rows = run_conjugate_bench(&cfg);
    assert_eq!(rows.len(), 1);
    let r = &rows[0];
    assert_eq!(r.model, "conjugate_hier");
    assert_eq!(r.n_certs, 2);
    assert!(r.ess_mh.is_finite() && r.ess_collapsed.is_finite());
    assert!(r.secs_mh > 0.0 && r.secs_collapsed > 0.0);
}
