//! The static-structure compiler end to end: promotion on the benchmark
//! models, bit-identical serving against the dynamic fused executors,
//! transparent demotion (windowed contexts, θ-dependent branching,
//! discrete-trace drift), plate grouping, masked-Gibbs isolation, and
//! index-set minibatching.

use dynamicppl::context::{register_subset, Context};
use dynamicppl::gradient::{Backend, LogDensity, NativeDensity};
use dynamicppl::inference::gibbs::GibbsGrad;
use dynamicppl::inference::{sample_chain, Gibbs, GibbsBlock, Nuts, SamplerKind};
use dynamicppl::model::compiled::try_compile;
use dynamicppl::model::count_obs_sites;
use dynamicppl::models::logreg::logreg_n;
use dynamicppl::models::logreg_tall::logreg_tall_n;
use dynamicppl::models::{build_small, ALL_MODELS};
use dynamicppl::prelude::*;
use dynamicppl::vi::MinibatchTarget;

#[cfg(feature = "telemetry")]
use dynamicppl::obs::metrics::{self, Counter};

/// Table-1 models plus the tall flagship.
fn bench_models() -> Vec<&'static str> {
    ALL_MODELS.iter().copied().chain(["logreg_tall"]).collect()
}

fn assert_bits_eq(label: &str, lp_a: f64, lp_b: f64, g_a: &[f64], g_b: &[f64]) {
    assert_eq!(
        lp_a.to_bits(),
        lp_b.to_bits(),
        "{label}: logp {lp_a} vs {lp_b}"
    );
    assert_eq!(g_a.len(), g_b.len(), "{label}: gradient length");
    for (i, (a, b)) in g_a.iter().zip(g_b).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{label} grad[{i}]: {a} vs {b}");
    }
}

/// Compiled serving is bitwise identical to the dynamic fused walk on
/// every benchmark model, across every servable context and several θ
/// points — and the recorded program's site/dim bookkeeping matches the
/// dynamic executors' own counts.
#[test]
fn compiled_replay_is_bitwise_identical_on_every_benchmark_model() {
    let promoted_expected = ["gauss_unknown", "hier_poisson", "logreg_tall"];
    for name in bench_models() {
        let bm = build_small(name, 7);
        let m = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let tvi = init_typed(m, &mut rng);
        let dim = tvi.dim();
        let mut ld = NativeDensity::fused(m, &tvi);
        let mut ld_dyn = NativeDensity::fused_dynamic(m, &tvi);
        let contexts = [
            Context::Default,
            Context::Likelihood,
            Context::Prior,
            Context::MiniBatch { scale: 1.7 },
        ];
        for ctx in contexts {
            ld.ctx = ctx;
            ld_dyn.ctx = ctx;
            for point in 0..3usize {
                let theta: Vec<f64> = tvi
                    .unconstrained
                    .iter()
                    .enumerate()
                    .map(|(i, x)| x * 0.3 + 0.02 * (((i + point) % 5) as f64) - 0.04)
                    .collect();
                let mut g_c = vec![0.0; dim];
                let mut g_d = vec![0.0; dim];
                let lp_c = ld.logp_grad_into(&theta, &mut g_c);
                let lp_d = ld_dyn.logp_grad_into(&theta, &mut g_d);
                let label = format!("{name} {ctx:?} point {point}");
                assert_bits_eq(&label, lp_c, lp_d, &g_c, &g_d);
            }
        }
        if let Some(prog) = ld.compiled_program() {
            assert_eq!(prog.n_obs(), count_obs_sites(m, &tvi), "{name}: n_obs");
            assert_eq!(prog.dim(), dim, "{name}: dim");
        } else {
            assert!(
                !promoted_expected.contains(&name),
                "{name} must promote to the compiled replay"
            );
        }
    }
}

/// Seeded NUTS produces draw-for-draw identical chains whether the
/// density serves the compiled program or the dynamic walk.
#[test]
fn seeded_nuts_is_draw_for_draw_identical_compiled_vs_dynamic() {
    for name in bench_models() {
        let bm = build_small(name, 13);
        let m = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let tvi = init_typed(m, &mut rng);
        let kind = SamplerKind::Nuts(Nuts {
            step_size: bm.step_size,
            ..Nuts::default()
        });
        let ld = NativeDensity::fused(m, &tvi);
        let ld_dyn = NativeDensity::fused_dynamic(m, &tvi);
        let a = sample_chain(&ld, &tvi, &kind, 40, 40, 29);
        let b = sample_chain(&ld_dyn, &tvi, &kind, 40, 40, 29);
        assert_eq!(a.len(), b.len(), "{name}: chain length");
        for (la, lb) in a.logp.iter().zip(&b.logp) {
            assert_eq!(la.to_bits(), lb.to_bits(), "{name}: logp trace diverged");
        }
        for (ra, rb) in a.rows().iter().zip(b.rows()) {
            for (x, y) in ra.iter().zip(rb) {
                assert_eq!(x.to_bits(), y.to_bits(), "{name}: draws diverged");
            }
        }
    }
}

model! {
    /// θ-dependent structure: the observation's distribution family
    /// follows the sampled sign of `m`, so the tilde walk is not static.
    pub Branchy {
        y: f64,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        if m > c::<T>(0.0) {
            obs!(api, this.y => Normal(m, c(1.0)));
        } else {
            obs!(api, this.y => Exponential(c(1.5)));
        }
    }
}

/// A θ-dependent branch flips the recorded structure between the two
/// recording passes: the compiler must refuse to promote, and the density
/// keeps serving the dynamic walk bitwise.
#[test]
fn theta_dependent_branching_never_promotes() {
    let m = Branchy { y: 0.5 };
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let mut tvi = init_typed(&m, &mut rng);
    // park θ just below the branch point: the verification recording at
    // θ + 0.125 takes the other arm
    tvi.unconstrained[0] = -0.06;
    assert!(try_compile(&m, &tvi).is_none(), "branchy model promoted");

    let ld = NativeDensity::fused(&m, &tvi);
    for theta0 in [-0.3, -0.06, 0.2] {
        let theta = [theta0];
        let mut g_c = vec![0.0; 1];
        let mut g_d = vec![0.0; 1];
        let lp_c = ld.logp_grad_into(&theta, &mut g_c);
        let lp_d = typed_grad_fused_into(&m, &tvi, &theta, Context::Default, &mut g_d);
        assert_bits_eq(&format!("branchy at {theta0}"), lp_c, lp_d, &g_c, &g_d);
    }
    assert!(
        ld.compiled_program().is_none(),
        "branchy density must stay dynamic"
    );
}

/// Windowed contexts are served by transparent demotion to the dynamic
/// executors — bitwise — and the telemetry counters record exactly one
/// promotion plus one demotion per windowed evaluation. Promotion
/// survives the excursion: back at `Default` the program serves again.
#[test]
fn windowed_contexts_demote_to_the_dynamic_walk_bitwise() {
    let bm = build_small("hier_poisson", 17);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(17);
    let tvi = init_typed(m, &mut rng);
    let dim = tvi.dim();
    let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
    let mut g_c = vec![0.0; dim];
    let mut g_d = vec![0.0; dim];

    #[cfg(feature = "telemetry")]
    let _ = metrics::take_local();

    let mut ld = NativeDensity::fused(m, &tvi);
    let mut ld_dyn = NativeDensity::fused_dynamic(m, &tvi);
    let lp_c = ld.logp_grad_into(&theta, &mut g_c);
    let lp_d = ld_dyn.logp_grad_into(&theta, &mut g_d);
    assert_bits_eq("hier_poisson Default", lp_c, lp_d, &g_c, &g_d);
    assert!(ld.compiled_program().is_some(), "hier_poisson must promote");

    let set = register_subset(vec![3, 7, 8, 22, 41]);
    let windows = [
        Context::Subsample {
            lo: 5,
            hi: 20,
            scale: 2.5,
        },
        Context::SubsampleIdx { set, scale: 10.0 },
    ];
    for ctx in windows {
        ld.ctx = ctx;
        ld_dyn.ctx = ctx;
        let lp_c = ld.logp_grad_into(&theta, &mut g_c);
        let lp_d = ld_dyn.logp_grad_into(&theta, &mut g_d);
        assert_bits_eq(&format!("{ctx:?}"), lp_c, lp_d, &g_c, &g_d);
    }

    ld.ctx = Context::Default;
    ld_dyn.ctx = Context::Default;
    let lp_c = ld.logp_grad_into(&theta, &mut g_c);
    let lp_d = ld_dyn.logp_grad_into(&theta, &mut g_d);
    assert_bits_eq("hier_poisson Default (after)", lp_c, lp_d, &g_c, &g_d);
    assert!(ld.compiled_program().is_some());

    #[cfg(feature = "telemetry")]
    {
        let snap = metrics::take_local();
        assert_eq!(snap.get(Counter::StaticPromotions), 1, "one compile");
        assert_eq!(
            snap.get(Counter::StaticDemotions),
            2,
            "one demotion per windowed evaluation"
        );
    }
}

model! {
    /// Discrete mixture: a Bernoulli indicator selects the observation
    /// mean — static only for a fixed discrete trace.
    pub MixFix {
        y: f64,
    }
    fn body<T>(this, api) {
        let s = tilde!(api, s ~ Normal(c(0.0), c(1.0)));
        let z = tilde_int!(api, z ~ Bernoulli(c(0.3)));
        let mu = if z == 1 { s + c(3.0) } else { s - c(3.0) };
        obs!(api, this.y => Normal(mu, c(1.0)));
    }
}

/// The compiled program pins the discrete trace it was recorded under: a
/// Gibbs-style flip of `z` fails `matches_discrete`, and a density built
/// on the flipped trace recompiles and agrees with the dynamic walk.
#[test]
fn discrete_trace_drift_demotes_the_snapshot() {
    let m = MixFix { y: 2.0 };
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let tvi = init_typed(&m, &mut rng);
    let theta = [0.4];
    let prog = try_compile(&m, &tvi).expect("fixed discrete trace is static");
    assert!(prog.matches_discrete(&tvi));

    let mut g_c = vec![0.0; 1];
    let mut g_d = vec![0.0; 1];
    let lp_c = prog.logp_grad_into(&tvi, &theta, Context::Default, &mut g_c);
    let lp_d = typed_grad_fused_into(&m, &tvi, &theta, Context::Default, &mut g_d);
    assert_bits_eq("mix original trace", lp_c, lp_d, &g_c, &g_d);

    // flip the indicator: the snapshot no longer matches…
    let mut flipped = tvi.clone();
    flipped.discrete[0] = 1 - flipped.discrete[0];
    assert!(!prog.matches_discrete(&flipped));
    // …and it must not: the flipped trace scores a different joint
    let lp_flip = typed_grad_fused_into(&m, &flipped, &theta, Context::Default, &mut g_d);
    assert_ne!(lp_d.to_bits(), lp_flip.to_bits());

    // a density built on the flipped trace recompiles and agrees bitwise
    let ld = NativeDensity::fused(&m, &flipped);
    let lp_c2 = ld.logp_grad_into(&theta, &mut g_c);
    let lp_d2 = typed_grad_fused_into(&m, &flipped, &theta, Context::Default, &mut g_d);
    assert_bits_eq("mix flipped trace", lp_c2, lp_d2, &g_c, &g_d);
    assert!(ld.compiled_program().is_some(), "flipped trace is static too");
}

/// A live compiled program must not leak into blocked Gibbs: the masked
/// fused conditionals bypass the compiled replay, so seeded sweeps are
/// bitwise identical with and without a promoted program in scope.
#[test]
fn masked_gibbs_is_unaffected_by_a_live_compiled_program() {
    let bm = build_small("gauss_unknown", 23);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(23);
    let tvi = init_typed(m, &mut rng);
    let gibbs = Gibbs {
        blocks: vec![
            GibbsBlock::rwmh(&["s"], 0.3),
            GibbsBlock::hmc(&["m"], 0.02, 8),
        ],
        grad: GibbsGrad::Fused,
        // this test pins the MH proposal stream; a collapsed s-block
        // would consume a different rng sequence
        collapse: false,
    };

    let mut r = Xoshiro256pp::seed_from_u64(91);
    let base = gibbs.sample(m, &tvi, 20, 20, &mut r);

    // promote a program for the same model and keep it hot across the run
    let ld = NativeDensity::fused(m, &tvi);
    let theta = tvi.unconstrained.clone();
    let mut g = vec![0.0; tvi.dim()];
    let lp = ld.logp_grad_into(&theta, &mut g);
    assert!(lp.is_finite());
    assert!(ld.compiled_program().is_some(), "gauss_unknown must promote");

    let mut r = Xoshiro256pp::seed_from_u64(91);
    let again = gibbs.sample(m, &tvi, 20, 20, &mut r);
    let _ = ld.logp_grad_into(&theta, &mut g);

    assert_eq!(base.logps.len(), again.logps.len());
    for (a, b) in base.logps.iter().zip(&again.logps) {
        assert_eq!(a.to_bits(), b.to_bits(), "Gibbs logp trace diverged");
    }
    for (ra, rb) in base.rows.iter().zip(&again.rows) {
        for (x, y) in ra.iter().zip(rb) {
            assert_eq!(x.to_bits(), y.to_bits(), "Gibbs draws diverged");
        }
    }
}

/// Plate grouping: consecutive observe sites sharing one distribution
/// family and parameter slots collapse into row-batched plate kernels,
/// counted per compiled gradient pass; interleaved raw-logp glue falls
/// back to the flat per-site replay without losing promotion.
#[test]
fn plate_grouping_forms_row_batched_kernels() {
    let bm = build_small("hier_poisson", 11);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let tvi = init_typed(m, &mut rng);
    let prog = try_compile(m, &tvi).expect("hier_poisson is static");
    assert_eq!(prog.n_plates(), 10, "one plate per group");
    assert_eq!(prog.plate_rows(), 50, "10 groups x 5 counts");
    assert_eq!(prog.n_obs(), count_obs_sites(m, &tvi));

    #[cfg(feature = "telemetry")]
    {
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.3).collect();
        let mut g = vec![0.0; prog.dim()];
        let _ = metrics::take_local();
        let lp = prog.logp_grad_into(&tvi, &theta, Context::Default, &mut g);
        assert!(lp.is_finite());
        let snap = metrics::take_local();
        assert_eq!(
            snap.get(Counter::PlateKernelCalls),
            10,
            "one row-batched kernel call per plate per pass"
        );
    }

    // tall flagship: per-row raw-logp glue defeats plate grouping, but
    // the flat slot-indexed replay still promotes — and the window-aware
    // body's `skip_obs` brackets must not double-count sites
    let bm = logreg_tall_n(19, 64, 4);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(19);
    let tvi = init_typed(m, &mut rng);
    let prog = try_compile(m, &tvi).expect("logreg_tall is static");
    assert_eq!(prog.n_plates(), 0, "raw-logp rows do not plate");
    assert_eq!(prog.n_obs(), count_obs_sites(m, &tvi));
    assert_eq!(prog.n_obs(), 64, "one site per row, none double-counted");
}

/// Index-set minibatching: contiguous sets reproduce the equivalent
/// `Subsample` windows bitwise, and a strided (genuinely non-contiguous)
/// partition keeps the estimator exactly unbiased.
#[test]
fn index_set_minibatching_matches_windows_and_stays_unbiased() {
    let bm = logreg_n(31, 48, 5);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(31);
    let tvi = init_typed(m, &mut rng);
    let theta: Vec<f64> = (0..5).map(|i| 0.15 * (i as f64) - 0.3).collect();

    // contiguous index sets ≡ the equivalent Subsample windows, bitwise
    let sets: Vec<Vec<u32>> = (0..3u32)
        .map(|k| (k * 16..(k + 1) * 16).collect())
        .collect();
    let target = MinibatchTarget::with_index_sets(m, &tvi, sets, Backend::ReverseFused);
    assert_eq!(target.n_blocks(), 3);
    for k in 0..3 {
        let ld = target.block(k);
        assert!(matches!(ld.ctx, Context::SubsampleIdx { .. }));
        let mut g_i = vec![0.0; 5];
        let lp_i = ld.logp_grad_into(&theta, &mut g_i);
        let ctx = Context::Subsample {
            lo: k * 16,
            hi: (k + 1) * 16,
            scale: 3.0,
        };
        let mut g_w = vec![0.0; 5];
        let lp_w = typed_grad_fused_into(m, &tvi, &theta, ctx, &mut g_w);
        assert_bits_eq(&format!("block {k} vs window"), lp_i, lp_w, &g_i, &g_w);
    }

    // strided partition: the block average recovers the full-data
    // gradient exactly (the unbiasedness contract of windowed blocks)
    let strided: Vec<Vec<u32>> = (0..3u32)
        .map(|r| (0..48u32).filter(|i| i % 3 == r).collect())
        .collect();
    let target = MinibatchTarget::with_index_sets(m, &tvi, strided, Backend::ReverseFused);
    assert_eq!(target.n_blocks(), 3);
    let (lp_full, g_full) = typed_grad_fused(m, &tvi, &theta, Context::Default);
    assert!(lp_full.is_finite());
    let mut lp_avg = 0.0;
    let mut g_avg = vec![0.0; 5];
    for k in 0..3 {
        let mut g = vec![0.0; 5];
        let lp = target.block(k).logp_grad_into(&theta, &mut g);
        lp_avg += lp / 3.0;
        for (a, b) in g_avg.iter_mut().zip(&g) {
            *a += b / 3.0;
        }
    }
    assert!(
        (lp_avg - lp_full).abs() < 1e-9,
        "E[subsampled logp] {lp_avg} vs full {lp_full}"
    );
    for (i, (a, b)) in g_avg.iter().zip(&g_full).enumerate() {
        assert!(
            (a - b).abs() < 1e-9 * (1.0 + b.abs()),
            "E[grad][{i}]: {a} vs {b}"
        );
    }
}
