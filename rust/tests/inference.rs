//! Statistical end-to-end tests: samplers must recover known posteriors
//! through every backend, including the full AOT path.
//!
//! Baselines assume the `init_step_size` probe is **on** by default for
//! `Hmc`/`Nuts` (re-baselined when `adapt::find_initial_step_size` became
//! the default warmup entry point): the probe consumes RNG draws before
//! the first iteration, so seeded draw streams differ from the pre-probe
//! era while every posterior tolerance below is unchanged.

use dynamicppl::context::Context;
use dynamicppl::gradient::{Backend, NativeDensity};
use dynamicppl::inference::{sample_chain, Hmc, Nuts, RwMh, SamplerKind};
use dynamicppl::model::init_typed;
use dynamicppl::models::{build_small, gauss::gauss_unknown_n};
use dynamicppl::prelude::*;
use dynamicppl::runtime::{artifact_exists, artifacts_dir, XlaDensity};
use dynamicppl::stanlike::stanlike_density;
use dynamicppl::util::stats;

/// Conjugate-ish check: gauss_unknown with many observations concentrates
/// around the data mean/variance (ground truth m=1.5, sd=0.7 → s=0.49).
fn check_gauss_posterior(chain: &dynamicppl::chain::Chain, label: &str) {
    let m = chain.column("m").unwrap();
    let s = chain.column("s").unwrap();
    assert!(
        (stats::mean(&m) - 1.5).abs() < 0.1,
        "{label}: posterior mean of m = {}",
        stats::mean(&m)
    );
    assert!(
        (stats::mean(&s) - 0.49).abs() < 0.1,
        "{label}: posterior mean of s = {}",
        stats::mean(&s)
    );
}

#[test]
fn nuts_recovers_gauss_unknown_tape() {
    let bm = gauss_unknown_n(1, 500);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::new(bm.model.as_ref(), &tvi, Backend::Reverse);
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Nuts(Nuts::default()), 600, 2000, 3);
    check_gauss_posterior(&chain, "nuts+tape");
}

#[test]
fn hmc_recovers_gauss_unknown_stanlike() {
    let bm = gauss_unknown_n(2, 500);
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = stanlike_density(&bm);
    let chain = sample_chain(
        ld.as_ref(),
        &tvi,
        &SamplerKind::Hmc(Hmc {
            step_size: 0.01,
            n_leapfrog: 16,
            ..Hmc::default()
        }),
        800,
        3000,
        4,
    );
    check_gauss_posterior(&chain, "hmc+stanlike");
}

#[test]
fn hmc_recovers_gauss_unknown_xla_full_workload() {
    // Uses the full 10,000-observation artifact: the paper's workload
    // through the complete three-layer stack.
    if !artifact_exists("gauss_unknown") {
        eprintln!("SKIP: artifact missing");
        return;
    }
    let bm = dynamicppl::models::build("gauss_unknown", 42);
    let mut rng = Xoshiro256pp::seed_from_u64(42);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = XlaDensity::load(&artifacts_dir(), "gauss_unknown", bm.theta_dim, &bm.data).unwrap();
    let chain = sample_chain(
        &ld,
        &tvi,
        &SamplerKind::Hmc(Hmc {
            step_size: 0.005,
            n_leapfrog: 8,
            ..Hmc::default()
        }),
        500,
        1500,
        5,
    );
    // with 10k observations the posterior is very tight
    let m = chain.column("m").unwrap();
    assert!(
        (stats::mean(&m) - 1.5).abs() < 0.05,
        "xla: posterior mean of m = {}",
        stats::mean(&m)
    );
    assert!(chain.stats.accept_rate > 0.5);
}

#[test]
fn mh_matches_hmc_on_small_model() {
    // Two very different samplers must agree on the posterior.
    let bm = build_small("hier_poisson", 8);
    let mut rng = Xoshiro256pp::seed_from_u64(8);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = stanlike_density(&bm);
    let mh = sample_chain(
        ld.as_ref(),
        &tvi,
        &SamplerKind::RwMh(RwMh::default()),
        4000,
        30_000,
        11,
    );
    let hmc = sample_chain(
        ld.as_ref(),
        &tvi,
        &SamplerKind::Hmc(Hmc {
            step_size: 0.05,
            n_leapfrog: 8,
            ..Hmc::default()
        }),
        1500,
        8000,
        12,
    );
    let a0_mh = stats::mean(&mh.column("a0").unwrap());
    let a0_hmc = stats::mean(&hmc.column("a0").unwrap());
    assert!(
        (a0_mh - a0_hmc).abs() < 0.15,
        "MH {a0_mh} vs HMC {a0_hmc} disagree on a0 posterior"
    );
}

#[test]
fn likelihood_context_excludes_prior_in_sampler_target() {
    // Sampling the LikelihoodContext of gaussian_10kd (flat prior
    // contribution removed) must not blow up — a regression guard on
    // context plumbing through densities.
    let bm = build_small("gaussian_10kd", 3);
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let mut ld = NativeDensity::new(bm.model.as_ref(), &tvi, Backend::Reverse);
    ld.ctx = Context::Prior; // prior-only target == the model itself here
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Hmc(Hmc::default()), 300, 1000, 6);
    let x0 = chain.column("x[0]").unwrap();
    assert!(stats::mean(&x0).abs() < 0.2);
    assert!((stats::variance(&x0) - 1.0).abs() < 0.35);
}

#[test]
fn enumerate_gibbs_recovers_discrete_latent_mixture_end_to_end() {
    // Satellite coverage: BlockSampler::Enumerate on a discrete-latent
    // model, end to end — unknown component means (HMC block) plus one
    // Bernoulli assignment per observation (Enumerate block).
    model! {
        pub MixTwo {
            y: Vec<f64>,
        }
        fn body<T>(this, api) {
            let mu0 = tilde!(api, mu0 ~ Normal(c(-2.0), c(2.0)));
            let mu1 = tilde!(api, mu1 ~ Normal(c(2.0), c(2.0)));
            check_reject!(api);
            for i in 0..this.y.len() {
                let z = tilde_int!(api, z[i] ~ Bernoulli(c(0.5)));
                let mu = if z == 1 { mu1 } else { mu0 };
                obs!(api, this.y[i] => Normal(mu, c(0.8)));
            }
        }
    }

    // two well-separated clusters at ±2 (labels fixed by the priors)
    let mut rng = Xoshiro256pp::seed_from_u64(44);
    let mut y = Vec::new();
    let mut truth = Vec::new();
    for i in 0..24 {
        let one = i % 2 == 0;
        truth.push(one);
        let center = if one { 2.0 } else { -2.0 };
        y.push(center + 0.8 * rng.normal());
    }
    let m = MixTwo { y };
    let tvi = dynamicppl::model::init_typed(&m, &mut rng);
    let gibbs = dynamicppl::inference::Gibbs::new(vec![
        dynamicppl::inference::GibbsBlock::hmc(&["mu0", "mu1"], 0.05, 8),
        dynamicppl::inference::GibbsBlock::enumerate(&["z"]),
    ]);
    let out = gibbs.sample(&m, &tvi, 800, 3000, &mut rng);

    // column order follows visit order: mu0, mu1, z[0..24]
    let mu0 = stats::mean(&out.rows.iter().map(|r| r[0]).collect::<Vec<_>>());
    let mu1 = stats::mean(&out.rows.iter().map(|r| r[1]).collect::<Vec<_>>());
    assert!((mu0 + 2.0).abs() < 0.5, "mu0 = {mu0}");
    assert!((mu1 - 2.0).abs() < 0.5, "mu1 = {mu1}");

    // posterior assignments recover the generating labels
    let mut correct = 0;
    for (i, &one) in truth.iter().enumerate() {
        let freq = stats::mean(&out.rows.iter().map(|r| r[2 + i]).collect::<Vec<_>>());
        if (freq > 0.5) == one {
            correct += 1;
        }
    }
    assert!(correct >= 22, "only {correct}/24 assignments recovered");
}
