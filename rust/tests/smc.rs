//! End-to-end tests of the particle-inference subsystem: SMC evidence
//! against closed forms (conjugate + Kalman), bitwise determinism of
//! parallel propagation, typed-vs-boxed replay equivalence, mid-sweep
//! demotion on dynamic structure changes, ancestor-sampling mixing, and
//! Particle-Gibbs agreement with both the exact smoother and the
//! HMC-within-Gibbs baseline.

use dynamicppl::inference::{csmc_sweep, Csmc, Gibbs, GibbsBlock, Smc};
use dynamicppl::model::init_trace;
use dynamicppl::models::build_small;
use dynamicppl::particle::count_observes;
use dynamicppl::prelude::*;
use dynamicppl::util::stats;
use dynamicppl::varinfo::{TypedVarInfo, UntypedVarInfo};
use rand_core::RngCore;

// ------------------------------------------------------------ models

model! {
    /// Conjugate Normal–Normal: m ~ N(0,1); y_t ~ N(m, 1).
    pub NormalNormal {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m, c(1.0)));
        }
    }
}

model! {
    /// Linear-Gaussian state space: h_0 ~ N(0,1);
    /// h_t ~ N(φ·h_{t−1}, q); y_t ~ N(h_t, r) — Kalman ground truth.
    pub LinearSsm {
        y: Vec<f64>,
        phi: f64,
        q: f64,
        r: f64,
    }
    fn body<T>(this, api) {
        let mut h_prev = tilde!(api, h[0] ~ Normal(c(0.0), c(1.0)));
        obs!(api, this.y[0] => Normal(h_prev, c(this.r)));
        for t in 1..this.y.len() {
            let h_t = tilde!(api, h[t] ~ Normal(h_prev * this.phi, c(this.q)));
            obs!(api, this.y[t] => Normal(h_t, c(this.r)));
            h_prev = h_t;
        }
    }
}

model! {
    /// Dynamic structure: a mid-sequence Bernoulli latent decides whether
    /// an `extra` variable exists for the rest of the trajectory. The
    /// latent sits *between* observe statements, so a resampling fork can
    /// regenerate it mid-sweep and flip the trace layout under a promoted
    /// typed cloud — the demotion trigger.
    pub DynStructure {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m0 = tilde!(api, m0 ~ Normal(c(0.0), c(1.0)));
        obs!(api, this.y[0] => Normal(m0, c(1.0)));
        // rare branch: most prior clouds share a layout (→ promotion), and
        // regeneration flips it often enough that ~40% of promoted runs
        // demote mid-sweep while ~40% finish fully typed
        let z = tilde_int!(api, z ~ Bernoulli(c(0.03)));
        let mu = if z == 1 {
            tilde!(api, extra ~ Normal(c(0.0), c(1.0))) + m0
        } else {
            m0
        };
        for t in 1..this.y.len() {
            obs!(api, this.y[t] => Normal(mu, c(1.0)));
        }
    }
}

// -------------------------------------------------- closed-form oracles

/// Sequential conjugate log-evidence of the Normal–Normal model.
fn conjugate_log_evidence(y: &[f64]) -> f64 {
    let (mut mu, mut tau2) = (0.0f64, 1.0f64);
    let mut lz = 0.0;
    for &yt in y {
        let pv = 1.0 + tau2;
        lz += Normal::new(mu, pv.sqrt()).logpdf(yt);
        let k = tau2 / pv;
        mu += k * (yt - mu);
        tau2 *= 1.0 - k;
    }
    lz
}

/// Kalman filter log-likelihood + RTS smoother means for [`LinearSsm`].
fn kalman(y: &[f64], phi: f64, q: f64, r: f64) -> (f64, Vec<f64>) {
    let t_len = y.len();
    let (q2, r2) = (q * q, r * r);
    let mut mf = Vec::with_capacity(t_len); // filtered means
    let mut pf = Vec::with_capacity(t_len); // filtered variances
    let mut mp = Vec::with_capacity(t_len); // predicted means
    let mut pp = Vec::with_capacity(t_len); // predicted variances
    let mut ll = 0.0;
    for t in 0..t_len {
        let (m_pred, p_pred) = if t == 0 {
            (0.0, 1.0)
        } else {
            (phi * mf[t - 1], phi * phi * pf[t - 1] + q2)
        };
        mp.push(m_pred);
        pp.push(p_pred);
        let s = p_pred + r2;
        ll += Normal::new(m_pred, s.sqrt()).logpdf(y[t]);
        let k = p_pred / s;
        mf.push(m_pred + k * (y[t] - m_pred));
        pf.push((1.0 - k) * p_pred);
    }
    // RTS smoother
    let mut ms = vec![0.0; t_len];
    ms[t_len - 1] = mf[t_len - 1];
    for t in (0..t_len - 1).rev() {
        let c = pf[t] * phi / pp[t + 1];
        ms[t] = mf[t] + c * (ms[t + 1] - mp[t + 1]);
    }
    (ll, ms)
}

fn ssm_fixture() -> LinearSsm {
    // simulated from the model itself (seeded), T = 10
    let (phi, q, r) = (0.8, 0.6, 0.5);
    let mut rng = Xoshiro256pp::seed_from_u64(2024);
    let mut h = rng.normal();
    let mut y = Vec::with_capacity(10);
    y.push(h + r * rng.normal());
    for _ in 1..10 {
        h = phi * h + q * rng.normal();
        y.push(h + r * rng.normal());
    }
    LinearSsm { y, phi, q, r }
}

// -------------------------------------------------------------- tests

#[test]
fn smc_512_particles_recovers_conjugate_evidence_within_two_percent() {
    let y = vec![0.3, -0.2, 0.6, 0.1, -0.4, 0.5, 0.0, 0.2];
    let want = conjugate_log_evidence(&y);
    let m = NormalNormal { y };
    let smc = Smc {
        n_particles: 2048,
        ..Smc::default()
    };
    let out = smc.run(&m, 99);
    // static model: the whole sweep must have run on the typed fast path
    assert!(out.cloud.is_typed());
    assert_eq!(out.demotions, 0);
    assert!(
        ((out.log_evidence - want) / want).abs() < 0.02,
        "SMC log Ẑ = {} vs analytic {want}",
        out.log_evidence
    );
}

#[test]
fn smc_recovers_kalman_evidence_on_state_space_model() {
    let m = ssm_fixture();
    let (ll, _) = kalman(&m.y, m.phi, m.q, m.r);
    let smc = Smc {
        n_particles: 4096,
        ..Smc::default()
    };
    let out = smc.run(&m, 5);
    assert_eq!(out.ess_trace.len(), 10);
    assert!(
        ((out.log_evidence - ll) / ll).abs() < 0.03,
        "PF log Ẑ = {} vs Kalman {ll}",
        out.log_evidence
    );
    // the filter had to resample at least once over 10 steps
    assert!(out.resamples >= 1);
}

#[test]
fn parallel_propagation_is_bitwise_deterministic_with_four_threads() {
    // acceptance criterion: threads = 4 must reproduce threads = 1 exactly
    let bm = build_small("sto_volatility", 3);
    let run = |threads: usize| {
        Smc {
            n_particles: 192,
            threads,
            ..Smc::default()
        }
        .run(bm.model.as_ref(), 77)
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a.log_evidence.to_bits(), b.log_evidence.to_bits());
    assert_eq!(a.resamples, b.resamples);
    assert_eq!(a.typed_steps, b.typed_steps);
    let (la, lb) = (a.cloud.log_weights(), b.cloud.log_weights());
    for (wa, wb) in la.iter().zip(&lb) {
        assert_eq!(wa.to_bits(), wb.to_bits());
    }
}

#[test]
fn typed_and_boxed_replay_are_bitwise_equivalent() {
    // The fast-path contract end-to-end: same seed ⇒ identical
    // log-evidence, weights and particle values on a continuous model
    // (gauss) and a simplex-structured single-lump model (HMM).
    for (name, probe) in [("gauss_unknown", "m"), ("hmm_semisup", "trans[0]")] {
        let bm = build_small(name, 11);
        let typed = Smc {
            n_particles: 48,
            ..Smc::default()
        }
        .run(bm.model.as_ref(), 7);
        let boxed = Smc {
            n_particles: 48,
            use_typed: false,
            ..Smc::default()
        }
        .run(bm.model.as_ref(), 7);
        assert!(typed.cloud.is_typed(), "{name} must promote");
        assert_eq!(typed.typed_steps, typed.cloud.n_obs(), "{name}");
        assert_eq!(typed.demotions, 0, "{name}");
        assert_eq!(
            typed.log_evidence.to_bits(),
            boxed.log_evidence.to_bits(),
            "{name}: evidence must be bit-identical across replay paths"
        );
        assert_eq!(typed.resamples, boxed.resamples, "{name}");
        let vn = VarName::parse(probe).unwrap();
        let (lt, lb) = (typed.cloud.log_weights(), boxed.cloud.log_weights());
        for i in 0..48 {
            assert_eq!(lt[i].to_bits(), lb[i].to_bits(), "{name} weight {i}");
            assert_eq!(
                typed.cloud.value_of(i, &vn),
                boxed.cloud.value_of(i, &vn),
                "{name} particle {i}"
            );
        }
    }
}

#[test]
fn dynamic_structure_demotes_mid_sweep_without_panicking() {
    // DynStructure flips its layout when a resampling fork regenerates z:
    // a promoted typed cloud must detect the mismatch, roll the step back
    // and finish boxed — bit-identical to a boxed-only run, never a panic.
    let m = DynStructure {
        y: vec![0.1, -0.2, 0.3, 0.05],
    };
    let mut saw_demotion = false;
    let mut saw_typed_completion = false;
    for seed in 0..120u64 {
        let cfg = Smc {
            n_particles: 8,
            ess_threshold: 1.0, // resample every step: maximal flag churn
            ..Smc::default()
        };
        let typed = cfg.run(&m, seed);
        let boxed = Smc {
            use_typed: false,
            ..cfg
        }
        .run(&m, seed);
        // whatever path the run took, it must equal the boxed ground truth
        assert_eq!(
            typed.log_evidence.to_bits(),
            boxed.log_evidence.to_bits(),
            "seed {seed}: demoted/typed run diverged from boxed"
        );
        if typed.demotions > 0 {
            saw_demotion = true;
            assert!(!typed.cloud.is_typed(), "seed {seed}: demoted cloud must be boxed");
        }
        if typed.cloud.is_typed() && typed.typed_steps == typed.cloud.n_obs() {
            saw_typed_completion = true;
        }
        if saw_demotion && saw_typed_completion {
            break;
        }
    }
    assert!(
        saw_demotion,
        "no seed in 0..120 exercised a mid-sweep demotion — model/flag setup broken"
    );
    assert!(
        saw_typed_completion,
        "no seed in 0..120 completed a fully-typed sweep"
    );
}

#[test]
fn ancestor_sampling_improves_path_mixing_on_sto_vol() {
    // Path degeneracy: plain CSMC almost never updates the *early* part
    // of the retained trajectory (lineages coalesce onto the reference's
    // prefix). PGAS resamples the retained path's ancestry each step, so
    // h[0] must change across sweeps much more often.
    let bm = dynamicppl::models::sto_vol::sto_volatility_t(3, 25);
    let model = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let reference = init_trace(model, &mut rng);
    let template = TypedVarInfo::from_untyped(&reference);
    let scope = [VarName::new("h")];
    let n_obs = Some(count_observes(model, &reference));
    let h0_of = |s: &UntypedVarInfo| -> f64 {
        s.get(&VarName::indexed("h", 0)).unwrap().value.as_f64().unwrap()
    };

    let changes = |ancestor_sampling: bool| -> usize {
        let mut state = reference.clone();
        let mut seeds = Xoshiro256pp::seed_from_u64(99);
        let cfg = Csmc {
            ancestor_sampling,
            ..Csmc::new(8)
        };
        let mut prev = h0_of(&state);
        let mut count = 0usize;
        for _ in 0..150 {
            state = csmc_sweep(
                model,
                &state,
                &scope,
                &cfg,
                seeds.next_u64(),
                n_obs,
                Some(&template),
            );
            let cur = h0_of(&state);
            if cur != prev {
                count += 1;
            }
            prev = cur;
        }
        count
    };

    let plain = changes(false);
    let pgas = changes(true);
    assert!(
        pgas > plain,
        "PGAS must mix the retained path's prefix better: h[0] updates {pgas} (PGAS) vs {plain} (plain CSMC) over 150 sweeps"
    );
}

#[test]
fn smc_chain_reports_evidence_and_posterior_on_sto_vol() {
    let bm = build_small("sto_volatility", 9);
    let smc = Smc {
        n_particles: 256,
        threads: 2,
        ..Smc::default()
    };
    let chain = smc.sample_chain(bm.model.as_ref(), 21);
    assert_eq!(chain.len(), 256);
    assert!(chain.stats.log_evidence.is_finite());
    // phi ∈ (−1, 1) by construction of the constrained chain
    let phi = chain.column("phi").unwrap();
    assert!(phi.iter().all(|&p| (-1.0..1.0).contains(&p)));
}

#[test]
fn particle_gibbs_matches_kalman_smoother_and_hmc_gibbs_baseline() {
    let m = ssm_fixture();
    let (_, smooth) = kalman(&m.y, m.phi, m.q, m.r);
    let mut rng = Xoshiro256pp::seed_from_u64(8);
    let tvi = dynamicppl::model::init_typed(&m, &mut rng);

    // Particle-Gibbs over the whole latent path (typed sweeps w/ PGAS)
    let pg = Gibbs::new(vec![GibbsBlock::particle_gibbs_as(&["h"], 48)]);
    let pg_out = pg.sample(&m, &tvi, 300, 2500, &mut rng);

    // HMC-within-Gibbs baseline on the same block
    let hmc = Gibbs::new(vec![GibbsBlock::hmc(&["h"], 0.05, 10)]);
    let hmc_out = hmc.sample(&m, &tvi, 1500, 6000, &mut rng);

    for t in [0usize, 4, 9] {
        let col = |rows: &Vec<Vec<f64>>| -> f64 {
            stats::mean(&rows.iter().map(|r| r[t]).collect::<Vec<_>>())
        };
        let pg_mean = col(&pg_out.rows);
        let hmc_mean = col(&hmc_out.rows);
        assert!(
            (pg_mean - smooth[t]).abs() < 0.15,
            "h[{t}]: PG {pg_mean} vs smoother {}",
            smooth[t]
        );
        assert!(
            (pg_mean - hmc_mean).abs() < 0.2,
            "h[{t}]: PG {pg_mean} vs HMC-Gibbs {hmc_mean}"
        );
    }
}

#[test]
fn particle_gibbs_smoke_on_hmm_semisup() {
    // The marginalized HMM has a single likelihood lump (one observe
    // statement): CSMC degenerates to a valid importance-within-Gibbs
    // kernel. Smoke-check that the sweep machinery handles a 115-dim
    // simplex-structured trace, on both replay paths.
    let bm = build_small("hmm_semisup", 6);
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let mut state = init_trace(bm.model.as_ref(), &mut rng);
    let template = TypedVarInfo::from_untyped(&state);
    let scope = [VarName::new("trans")];
    let n_obs = Some(count_observes(bm.model.as_ref(), &state));
    let cfg = Csmc::new(8);
    for it in 0..4 {
        // alternate typed / boxed sweeps: both must keep the trace whole
        let template_opt = if it % 2 == 0 { Some(&template) } else { None };
        state = csmc_sweep(
            bm.model.as_ref(),
            &state,
            &scope,
            &cfg,
            rng.next_u64(),
            n_obs,
            template_opt,
        );
    }
    // the trace stays complete and scorable
    let tvi = TypedVarInfo::from_untyped(&state);
    let lp = dynamicppl::model::typed_logp(
        bm.model.as_ref(),
        &tvi,
        &tvi.unconstrained,
        dynamicppl::context::Context::Default,
    );
    assert!(lp.is_finite());
}
