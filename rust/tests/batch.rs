//! End-to-end tests of the lane-batched SoA execution engine: K-lane
//! batched evaluation must be *bitwise invisible* in results — same
//! seeds, same draws — across all three wired sampler families
//! (multi-chain HMC/NUTS gangs, SMC cloud propagation, ADVI multi-sample
//! ELBO gradients), plus per-lane −∞ masking and demotion back to the
//! per-lane path on structure the batched walk cannot express.

use dynamicppl::context::Context;
use dynamicppl::gradient::{Backend, NativeDensity};
use dynamicppl::inference::{
    sample_chain, sample_chains_batched, Nuts, SamplerKind, Smc,
};
use dynamicppl::model::batched::typed_grad_batch_into;
use dynamicppl::model::{init_typed, typed_grad_fused_into};
use dynamicppl::models::gauss::gauss_unknown_n;
use dynamicppl::models::sto_vol::sto_volatility_t;
use dynamicppl::obs::metrics::{self, Counter};
use dynamicppl::particle::{BoxedCloud, TypedCloud};
use dynamicppl::prelude::*;
use dynamicppl::vi::Advi;

// ------------------------------------------------------------ models

model! {
    /// Conjugate Normal–Normal: m ~ N(0,1); y_t ~ N(m, 1).
    pub NormalNormal {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
        for &yi in &this.y {
            obs!(api, yi => Normal(m, c(1.0)));
        }
    }
}

model! {
    /// The observation sits outside `Uniform(0, m)`'s support whenever
    /// m < y — a clean per-lane −∞ source with a one-dimensional θ.
    pub HalfOpen {
        y: f64,
    }
    fn body<T>(this, api) {
        let m = tilde!(api, m ~ Normal(c(1.0), c(1.0)));
        obs!(api, this.y => Uniform(c(0.0), m));
    }
}

model! {
    /// Dynamic structure: a mid-sequence Bernoulli latent decides whether
    /// an `extra` variable exists — the structure the batched replay must
    /// refuse (discrete assume / per-lane layout divergence).
    pub DynStructure {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let m0 = tilde!(api, m0 ~ Normal(c(0.0), c(1.0)));
        obs!(api, this.y[0] => Normal(m0, c(1.0)));
        let z = tilde_int!(api, z ~ Bernoulli(c(0.03)));
        let mu = if z == 1 {
            tilde!(api, extra ~ Normal(c(0.0), c(1.0))) + m0
        } else {
            m0
        };
        for t in 1..this.y.len() {
            obs!(api, this.y[t] => Normal(mu, c(1.0)));
        }
    }
}

// ------------------------------------------- multi-chain HMC/NUTS lanes

/// Every lane of a batched gang must reproduce the solo chain with the
/// same seed bit-for-bit: same logp trace, same draws in every column.
fn check_gang_bitwise(model: &dyn dynamicppl::model::Model, seed0: u64, lanes: usize) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed0);
    let tvi = init_typed(model, &mut rng);
    let ld = NativeDensity::new(model, &tvi, Backend::ReverseFused);
    let kind = SamplerKind::Nuts(Nuts::default());
    let mc = sample_chains_batched(&ld, &tvi, &kind, 150, 200, seed0, lanes);
    assert_eq!(mc.chains.len(), lanes);
    for (l, batched) in mc.chains.iter().enumerate() {
        let solo = sample_chain(&ld, &tvi, &kind, 150, 200, seed0 + l as u64);
        assert_eq!(batched.logp.len(), solo.logp.len());
        for (i, (a, b)) in batched.logp.iter().zip(&solo.logp).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "lane {l}, draw {i}: logp");
        }
        for name in solo.names() {
            let ca = batched.column(name).unwrap();
            let cb = solo.column(name).unwrap();
            for (i, (a, b)) in ca.iter().zip(&cb).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "lane {l}, draw {i}: {name}");
            }
        }
    }
}

#[test]
fn lane_batched_nuts_is_bitwise_equal_to_solo_chains_gauss() {
    let bm = gauss_unknown_n(11, 200);
    check_gang_bitwise(bm.model.as_ref(), 40, 4);
}

#[test]
fn lane_batched_nuts_is_bitwise_equal_to_solo_chains_sto_vol() {
    // scalar-loop time-series model: the glue-heavy case where batched
    // tape topology mirroring is actually load-bearing
    let bm = sto_volatility_t(3, 25);
    check_gang_bitwise(bm.model.as_ref(), 60, 4);
}

// ----------------------------------------------- per-lane −∞ masking

#[test]
fn batched_gradients_mask_rejected_lanes_only() {
    let m = HalfOpen { y: 0.5 };
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let tvi = init_typed(&m, &mut rng);
    assert_eq!(tvi.dim(), 1);
    // lanes 1 and 3 put the observation outside the support (m < y)
    let thetas = [1.2f64, 0.2, 3.0, 0.49];
    let lanes = thetas.len();
    let mut lps = vec![0.0; lanes];
    let mut grads = vec![0.0; lanes];
    typed_grad_batch_into(&m, &tvi, &thetas, lanes, Context::Default, &mut lps, &mut grads);

    assert!(lps[0].is_finite() && lps[2].is_finite());
    assert_eq!(lps[1], f64::NEG_INFINITY);
    assert_eq!(lps[3], f64::NEG_INFINITY);
    assert_eq!(grads[1], 0.0);
    assert_eq!(grads[3], 0.0);
    assert_ne!(grads[0], 0.0);

    // each lane, rejected or not, is bitwise the sequential evaluation
    let mut g1 = vec![0.0; 1];
    for l in 0..lanes {
        let lp = typed_grad_fused_into(&m, &tvi, &thetas[l..l + 1], Context::Default, &mut g1);
        assert_eq!(lp.to_bits(), lps[l].to_bits(), "lane {l}: lp");
        assert_eq!(g1[0].to_bits(), grads[l].to_bits(), "lane {l}: grad");
    }
}

// ------------------------------------------------- SMC cloud batching

#[test]
fn batched_smc_is_bitwise_invisible_and_counted() {
    let m = NormalNormal {
        y: vec![0.4, -0.1, 0.7, 0.2, -0.3, 0.5],
    };
    let _ = metrics::take_local();
    let batched = Smc {
        n_particles: 64,
        ..Smc::default()
    }
    .run(&m, 9);
    let snap = metrics::take_local();
    // each observation step ran as one 64-lane replay
    assert!(snap.get(Counter::BatchedEvals) >= 1, "{snap:?}");
    assert!(snap.get(Counter::BatchedLanes) >= 64, "{snap:?}");

    let plain = Smc {
        n_particles: 64,
        use_batched: false,
        ..Smc::default()
    }
    .run(&m, 9);
    assert!(batched.cloud.is_typed() && plain.cloud.is_typed());
    assert_eq!(batched.log_evidence.to_bits(), plain.log_evidence.to_bits());
    assert_eq!(batched.resamples, plain.resamples);
    let (lb, lp) = (batched.cloud.log_weights(), plain.cloud.log_weights());
    let vn = VarName::new("m");
    for i in 0..64 {
        assert_eq!(lb[i].to_bits(), lp[i].to_bits(), "particle {i}");
        assert_eq!(batched.cloud.value_of(i, &vn), plain.cloud.value_of(i, &vn));
    }
}

#[test]
fn dynamic_or_discrete_structure_demotes_the_batched_walk() {
    let m = DynStructure { y: vec![0.3; 8] };
    // find a seed whose prior cloud shares one layout (promotable)
    let mut found = None;
    for seed in 0..50 {
        let boxed = BoxedCloud::from_prior(&m, 32, seed, 1);
        if let Some((cloud, _template)) = TypedCloud::promote(&boxed) {
            found = Some((cloud, seed));
            break;
        }
    }
    let (mut cloud, seed) = found.expect("no promotable prior cloud in 50 seeds");
    // the replay visits a discrete assume → the batched walk must refuse
    // (side-effect free: the cloud is untouched) ...
    assert!(cloud.advance_batched(&m, seed).is_none());
    // ... and the per-particle path re-runs the same step with the same
    // per-particle seed streams
    assert!(cloud.advance(&m, seed, 1).is_ok());

    // end-to-end: the default (batching-on) sweep stays bitwise equal to
    // a batching-off sweep even when every step demotes
    let a = Smc {
        n_particles: 32,
        ..Smc::default()
    }
    .run(&m, 5);
    let b = Smc {
        n_particles: 32,
        use_batched: false,
        ..Smc::default()
    }
    .run(&m, 5);
    assert_eq!(a.log_evidence.to_bits(), b.log_evidence.to_bits());
}

// ------------------------------------------------- ADVI ELBO batching

#[test]
fn advi_lane_batched_fit_is_bitwise_equal() {
    let bm = gauss_unknown_n(4, 100);
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::new(bm.model.as_ref(), &tvi, Backend::ReverseFused);
    let theta0 = tvi.unconstrained.clone();
    let cfg = |lanes: usize| Advi {
        grad_samples: 8,
        max_iters: 400,
        lanes,
        ..Advi::default()
    };
    let mut r1 = Xoshiro256pp::seed_from_u64(99);
    let f1 = cfg(1).fit(&ld, &theta0, &mut r1);
    let mut r8 = Xoshiro256pp::seed_from_u64(99);
    let f8 = cfg(8).fit(&ld, &theta0, &mut r8);
    assert_eq!(f1.elbo.to_bits(), f8.elbo.to_bits());
    assert_eq!(f1.approx.params.len(), f8.approx.params.len());
    for (i, (a, b)) in f1.approx.params.iter().zip(&f8.approx.params).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "param {i}");
    }
}
