//! Observability integration tests: seeded runs must trigger each
//! Stan-parity warning deterministically, telemetry must be free when
//! disabled (bit-identical draws, no arena growth), and the structured
//! counters must survive the trip from sampler to `METRICS.json`.

use dynamicppl::chain::{Chain, MultiChain};
use dynamicppl::gradient::NativeDensity;
use dynamicppl::inference::{sample_chain, sample_smc_chain, Hmc, Nuts, SamplerKind, Smc};
use dynamicppl::model::init_typed;
use dynamicppl::models::gauss::gauss_unknown_n;
use dynamicppl::obs::metrics::{self, Counter};
use dynamicppl::obs::profile::profile_model;
use dynamicppl::obs::report::RunReport;
use dynamicppl::prelude::*;

fn warning_kinds(rep: &RunReport) -> Vec<&'static str> {
    rep.warnings.iter().map(|w| w.kind()).collect()
}

#[test]
fn oversized_steps_trigger_the_divergence_warning() {
    // a fixed ε = 5 on a 500-observation posterior explodes every
    // trajectory: the divergence counter and its warning must fire
    let bm = gauss_unknown_n(1, 500);
    let mut rng = Xoshiro256pp::seed_from_u64(1);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Hmc(Hmc::paper(5.0)), 0, 50, 3);
    assert!(chain.stats.divergences > 0, "no divergences at ε = 5");
    assert_eq!(
        chain.stats.metrics.get(Counter::Divergences),
        chain.stats.divergences as u64,
        "counter must agree with the sampler stat"
    );
    assert!(chain.stats.metrics.get(Counter::GradEvals) > 0);
    assert!(chain.stats.metrics.get(Counter::LeapfrogSteps) > 0);
    let mc = MultiChain::new(vec![chain]);
    let rep = RunReport::from_chains("gauss_unknown", "hmc", &mc, Vec::new());
    assert!(
        warning_kinds(&rep).contains(&"divergences"),
        "{:?}",
        rep.warnings
    );
}

#[test]
fn shallow_trees_trigger_the_treedepth_warning() {
    // a tiny fixed ε cannot U-turn within two doublings: every post-warmup
    // transition saturates max_depth
    let bm = gauss_unknown_n(2, 100);
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let nuts = Nuts {
        step_size: 1e-4,
        max_depth: 2,
        init_step_size: false,
        ..Nuts::default()
    };
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Nuts(nuts), 0, 30, 5);
    assert!(chain.stats.max_treedepth_hits > 0, "no treedepth saturation");
    assert_eq!(
        chain.stats.metrics.get(Counter::MaxTreedepthHits),
        chain.stats.max_treedepth_hits as u64
    );
    let mc = MultiChain::new(vec![chain]);
    let rep = RunReport::from_chains("gauss_unknown", "nuts", &mc, Vec::new());
    assert!(
        warning_kinds(&rep).contains(&"max_treedepth"),
        "{:?}",
        rep.warnings
    );
}

#[test]
fn degenerate_chains_trigger_ess_and_rhat_warnings() {
    // two slow linear ramps with separated means: autocorrelation ≈ 1
    // (tiny ESS) and disjoint chain supports (huge split-R̂)
    let mut a = Chain::new(vec!["x".into()]);
    let mut b = Chain::new(vec!["x".into()]);
    for i in 0..400 {
        a.push(vec![(i as f64) * 0.001], 0.0);
        b.push(vec![5.0 + (i as f64) * 0.001], 0.0);
    }
    let mc = MultiChain::new(vec![a, b]);
    let rep = RunReport::from_chains("demo", "mh", &mc, Vec::new());
    let kinds = warning_kinds(&rep);
    assert!(kinds.contains(&"high_rhat"), "{kinds:?}");
    assert!(kinds.contains(&"low_ess"), "{kinds:?}");
}

#[test]
fn draws_are_bit_identical_with_telemetry_disabled() {
    // the runtime kill switch must change *nothing* about the sampled
    // stream — counters and energies only appear while it is on
    let bm = gauss_unknown_n(3, 200);
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let run = || sample_chain(&ld, &tvi, &SamplerKind::Nuts(Nuts::default()), 200, 300, 7);

    let on = run();
    assert!(!on.stats.metrics.is_empty(), "telemetry on but no counters");
    assert!(!on.stats.energies.is_empty(), "telemetry on but no energies");

    metrics::set_enabled(false);
    let off = run();
    metrics::set_enabled(true);
    assert!(off.stats.metrics.is_empty(), "counters leaked while disabled");
    assert!(off.stats.energies.is_empty(), "energies leaked while disabled");

    assert_eq!(on.len(), off.len());
    for (ra, rb) in on.rows().iter().zip(off.rows().iter()) {
        for (x, y) in ra.iter().zip(rb.iter()) {
            assert_eq!(x.to_bits(), y.to_bits(), "draws differ with telemetry off");
        }
    }
    for (x, y) in on.logp.iter().zip(&off.logp) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn disabled_telemetry_adds_no_arena_allocation() {
    // with the runtime guard off, repeated fused gradients must leave the
    // arena tape at steady-state capacity (the PR-3 zero-alloc guarantee)
    let bm = gauss_unknown_n(4, 100);
    let mut rng = Xoshiro256pp::seed_from_u64(4);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let theta = tvi.unconstrained.clone();
    let mut grad = vec![0.0; theta.len()];
    metrics::set_enabled(false);
    for _ in 0..3 {
        let _ = dynamicppl::model::typed_grad_fused_into(
            bm.model.as_ref(),
            &tvi,
            &theta,
            dynamicppl::context::Context::Default,
            &mut grad,
        );
    }
    let cap = dynamicppl::ad::arena::capacity_bytes();
    for _ in 0..50 {
        let _ = dynamicppl::model::typed_grad_fused_into(
            bm.model.as_ref(),
            &tvi,
            &theta,
            dynamicppl::context::Context::Default,
            &mut grad,
        );
    }
    assert_eq!(
        dynamicppl::ad::arena::capacity_bytes(),
        cap,
        "arena grew during disabled-telemetry gradient evaluations"
    );
    metrics::set_enabled(true);
    assert!(metrics::take_local().is_empty());
}

#[test]
fn smc_metrics_record_promotion_and_resampling() {
    model! {
        pub ObsSmc { y: Vec<f64>, }
        fn body<T>(this, api) {
            let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
            for &yi in &this.y {
                obs!(api, yi => Normal(m, c(0.5)));
            }
        }
    }
    let m = ObsSmc {
        y: vec![0.3, -0.2, 0.4, 0.1],
    };
    let smc = Smc {
        n_particles: 64,
        ess_threshold: 1.0, // resample every step
        ..Smc::default()
    };
    let chain = sample_smc_chain(&m, &smc, 17);
    let snap = &chain.stats.metrics;
    assert_eq!(snap.get(Counter::TypedPromotions), 1, "static model must promote");
    assert_eq!(snap.get(Counter::TypedDemotions), 0);
    assert!(snap.get(Counter::ResampleEvents) >= 1, "threshold 1.0 must resample");
    // the promotion/demotion counters must survive into METRICS.json
    let mc = MultiChain::new(vec![chain]);
    let rep = RunReport::from_chains("obs_smc", "smc", &mc, Vec::new());
    let json = rep.to_json();
    assert!(json.contains("\"typed_promotions\": 1"), "{json}");
    assert!(json.contains("\"resample_events\""));
    assert!(json.contains("\"log_evidence\""));
}

#[test]
fn advi_metrics_count_eta_trials() {
    let bm = gauss_unknown_n(5, 100);
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let chain = sample_chain(
        &ld,
        &tvi,
        &SamplerKind::Advi(dynamicppl::vi::Advi::default()),
        0,
        200,
        21,
    );
    let snap = &chain.stats.metrics;
    assert_eq!(
        snap.get(Counter::EtaTrials),
        dynamicppl::vi::ETA_CANDIDATES.len() as u64,
        "the default fit runs the full η ladder once"
    );
    assert!(snap.get(Counter::GradEvals) > 0);
    assert!(snap.get(Counter::ArenaEvals) > 0, "fused fit must hit the arena");
    assert!(snap.arena_nodes_per_eval().is_finite());
}

#[test]
fn profile_model_attributes_sites_across_all_four_paths() {
    model! {
        pub ObsProf { y: Vec<f64>, }
        fn body<T>(this, api) {
            let mu = tilde!(api, mu ~ Normal(c(0.0), c(1.0)));
            for &yi in &this.y {
                obs!(api, yi => Normal(mu, c(1.0)));
            }
        }
    }
    let m = ObsProf { y: vec![0.5, -0.5] };
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    let tvi = init_typed(&m, &mut rng);
    let theta = tvi.unconstrained.clone();
    let rows = profile_model(&m, &tvi, &theta, 11);
    for path in ["typed", "typed+fused", "untyped", "untyped+fused"] {
        let mu = rows
            .iter()
            .find(|r| r.path == path && r.site == "mu")
            .unwrap_or_else(|| panic!("no mu row for path {path}"));
        assert_eq!(mu.calls, 1);
        assert!(mu.logp.is_finite());
        assert!(
            rows.iter().any(|r| r.path == path && r.site == "obs[0]"),
            "no obs[0] row for path {path}"
        );
        assert!(rows.iter().any(|r| r.path == path && r.site == "obs[1]"));
    }
    // every path scores the same joint at the same point
    let mut totals = std::collections::HashMap::new();
    for r in &rows {
        *totals.entry(r.path).or_insert(0.0) += r.logp;
    }
    let t = totals["typed"];
    for p in ["typed+fused", "untyped", "untyped+fused"] {
        assert!((totals[p] - t).abs() < 1e-9, "{p} disagrees: {} vs {t}", totals[p]);
    }
}

#[test]
fn serve_counters_are_cataloged_and_reach_metrics_json() {
    use dynamicppl::obs::metrics::ALL_COUNTERS;
    use dynamicppl::serve::query::ServeQuery;
    use dynamicppl::serve::{FitSpec, ServeConfig, ServeHandle};

    // the serving counters are first-class catalog members
    for (c, key) in [
        (Counter::ServeQueries, "serve_queries"),
        (Counter::ServeCacheHits, "serve_cache_hits"),
        (Counter::ServeCacheMisses, "serve_cache_misses"),
        (Counter::ServeStreamUpdates, "serve_stream_updates"),
        (Counter::ServeEssRefits, "serve_ess_refits"),
        (Counter::ServeWarmStarts, "serve_warm_starts"),
    ] {
        assert!(ALL_COUNTERS.contains(&c), "{key} missing from the catalog");
        assert_eq!(c.key(), key);
    }

    // drive the real serving path and watch the counters move
    let _ = metrics::take_local(); // isolate from other tests on this thread
    let handle = ServeHandle::new(ServeConfig::default());
    handle
        .init_stream("normal_normal", vec![0.4, -0.1, 0.6, 0.2])
        .unwrap();
    let spec = FitSpec::smc(64, 3);
    let q = ServeQuery::Mean { param: "m".into() };
    handle.query("normal_normal", &spec, &q).unwrap(); // miss + fit
    handle.query("normal_normal", &spec, &q).unwrap(); // hit
    let snap = metrics::take_local();
    assert_eq!(snap.get(Counter::ServeQueries), 2);
    assert_eq!(snap.get(Counter::ServeCacheMisses), 1);
    assert_eq!(snap.get(Counter::ServeCacheHits), 1);

    // and they survive the trip into METRICS.json like every counter
    let mut chain = Chain::new(vec!["x".into()]);
    chain.push(vec![0.0], 0.0);
    chain.stats.metrics = snap;
    let mc = MultiChain::new(vec![chain]);
    let rep = RunReport::from_chains("serve", "smc", &mc, Vec::new());
    let json = rep.to_json();
    assert!(json.contains("\"serve_queries\": 2"), "{json}");
    assert!(json.contains("\"serve_cache_hits\": 1"), "{json}");
    assert!(json.contains("\"serve_cache_misses\": 1"), "{json}");
    assert!(json.contains("\"serve_stream_updates\": 0"), "{json}");
}

#[test]
fn metrics_json_reports_the_acceptance_keys() {
    // the acceptance-criteria keys for a NUTS run: per-chain divergences,
    // grad-eval counts, arena nodes/eval, promotion counters, wall split
    let bm = gauss_unknown_n(6, 100);
    let mut rng = Xoshiro256pp::seed_from_u64(6);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let chain = sample_chain(&ld, &tvi, &SamplerKind::Nuts(Nuts::default()), 100, 200, 13);
    let theta = tvi.unconstrained.clone();
    let profile = profile_model(bm.model.as_ref(), &tvi, &theta, 6);
    assert!(!profile.is_empty());
    let mc = MultiChain::new(vec![chain]);
    let rep = RunReport::from_chains("gauss_unknown", "nuts", &mc, profile);
    let json = rep.to_json();
    for key in [
        "\"divergences\"",
        "\"grad_evals\"",
        "\"leapfrog_steps\"",
        "\"arena_nodes\"",
        "\"arena_nodes_per_eval\"",
        "\"typed_promotions\"",
        "\"warmup_secs\"",
        "\"sampling_secs\"",
        "\"ebfmi\"",
        "\"profile\"",
        "\"warnings\"",
    ] {
        assert!(json.contains(key), "missing {key}");
    }
    assert_eq!(json.matches('{').count(), json.matches('}').count());
    assert!(!json.contains("NaN"));
    // the human rendering comes from the same structure
    let human = rep.render_human(&mc);
    assert!(human.contains("metrics:"));
    assert!(human.contains("per-site profile:"));
}
