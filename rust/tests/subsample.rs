//! Context-weight semantics across the executor stack, the
//! `Context::Subsample` tall-data estimator, and minibatched ADVI.
//!
//! - Table-driven equality of logp + gradients across all four flat
//!   monomorphizations (typed, untyped, typed-fused, untyped-fused) for
//!   every context, including windowed subsampling.
//! - Minibatch unbiasedness: the block average of `Subsample`-scaled
//!   gradients equals the full-data gradient exactly.
//! - Fused-path cost: out-of-window observations allocate **zero** arena
//!   nodes on a window-aware body.
//! - The ISSUE acceptance run: minibatched ADVI on a tall logistic
//!   regression reaches the full-data fit's posterior means within 5% at
//!   strictly lower wall-clock per iteration.
//! - Regression: prior-only evaluations are not poisoned by impossible
//!   observations (zero-weight −∞ likelihood terms).

use dynamicppl::ad::arena;
use dynamicppl::context::Context;
use dynamicppl::gradient::Backend;
use dynamicppl::model::count_obs_sites;
use dynamicppl::models::logreg::{logreg_n, LogReg};
use dynamicppl::models::logreg_tall::logreg_tall_n;
use dynamicppl::prelude::*;
use dynamicppl::runtime::DataInput;
use dynamicppl::vi::MinibatchTarget;

fn assert_grad_close(name: &str, got: &[f64], want: &[f64], rel: f64) {
    assert_eq!(got.len(), want.len(), "{name}: gradient length");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        let scale = 1.0 + b.abs();
        assert!(((a - b) / scale).abs() < rel, "{name} grad[{i}]: {a} vs {b}");
    }
}

model! {
    /// Context fixture: scalar + vector assumes, distribution observes,
    /// a raw likelihood site and a raw prior term — every accumulator
    /// path a context weight can touch.
    pub CtxFixture {
        y: Vec<f64>,
    }
    fn body<T>(this, api) {
        let s = tilde!(api, s ~ InverseGamma(c(2.0), c(3.0)));
        check_reject!(api);
        let sd = s.sqrt();
        let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), c(1.0), 3));
        for (i, &yi) in this.y.iter().enumerate() {
            let mu = w[i % 3] * 0.5 + s * 0.1;
            obs!(api, yi => Normal(mu, sd));
        }
        // raw likelihood site (counts as one more observation window slot)
        api.add_obs_logp((w[0] - w[1]) * (w[0] - w[1]) * (-0.25));
        // raw prior-side term (never windowed)
        api.add_prior_logp(w[2] * w[2] * (-0.05));
    }
}

/// All four flat monomorphizations must agree on logp and gradient under
/// every context, including windowed subsampling.
#[test]
fn context_weights_agree_across_all_four_executor_paths() {
    let m = CtxFixture {
        y: vec![0.3, -0.8, 1.1, 0.4, -0.2, 0.9],
    };
    let mut rng = Xoshiro256pp::seed_from_u64(3);
    let vi = init_trace(&m, &mut rng);
    let tvi = TypedVarInfo::from_untyped(&vi);
    let dim = tvi.dim();
    assert_eq!(count_obs_sites(&m, &tvi), 7, "6 dist observes + 1 raw site");
    let theta: Vec<f64> = (0..dim).map(|i| 0.11 * (i as f64) - 0.2).collect();

    let contexts = [
        Context::Default,
        Context::Prior,
        Context::Likelihood,
        Context::MiniBatch { scale: 2.5 },
        Context::Subsample { lo: 0, hi: usize::MAX, scale: 2.5 },
        Context::Subsample { lo: 1, hi: 4, scale: 7.0 / 3.0 },
        Context::Subsample { lo: 5, hi: 7, scale: 3.5 },
        Context::Subsample { lo: 0, hi: 0, scale: 1.0 },
    ];
    for ctx in contexts {
        let lp_typed = typed_logp(&m, &tvi, &theta, ctx);
        let lp_untyped = untyped_logp(&m, &vi, &theta, ctx);
        let (lp_tf, g_tf) = typed_grad_fused(&m, &tvi, &theta, ctx);
        let (lp_uf, g_uf) = untyped_grad_fused(&m, &vi, &theta, ctx);
        let (lp_fwd, g_fwd) = typed_grad_forward(&m, &tvi, &theta, ctx);
        let (lp_rev, g_rev) = typed_grad_reverse(&m, &tvi, &theta, ctx);
        for (label, lp) in [
            ("untyped", lp_untyped),
            ("typed-fused", lp_tf),
            ("untyped-fused", lp_uf),
            ("typed-forward", lp_fwd),
            ("typed-reverse", lp_rev),
        ] {
            assert!(
                (lp - lp_typed).abs() < 1e-9,
                "{ctx:?} {label}: logp {lp} vs typed {lp_typed}"
            );
        }
        assert_grad_close(&format!("{ctx:?} typed-fused vs forward"), &g_tf, &g_fwd, 1e-8);
        assert_grad_close(&format!("{ctx:?} untyped-fused vs forward"), &g_uf, &g_fwd, 1e-8);
        assert_grad_close(&format!("{ctx:?} reverse vs forward"), &g_rev, &g_fwd, 1e-8);
    }

    // MiniBatch ≡ Subsample with the full window, term for term
    let mb = typed_logp(&m, &tvi, &theta, Context::MiniBatch { scale: 2.5 });
    let ss = typed_logp(
        &m,
        &tvi,
        &theta,
        Context::Subsample { lo: 0, hi: usize::MAX, scale: 2.5 },
    );
    assert!((mb - ss).abs() < 1e-12, "{mb} vs {ss}");

    // windowed semantics decompose: prior + scale · (windowed likelihood)
    let prior = typed_logp(&m, &tvi, &theta, Context::Prior);
    let site = |i: usize| {
        typed_logp(
            &m,
            &tvi,
            &theta,
            Context::Subsample { lo: i, hi: i + 1, scale: 1.0 },
        ) - prior
    };
    let want = prior + 2.0 * (site(1) + site(2) + site(3));
    let got = typed_logp(
        &m,
        &tvi,
        &theta,
        Context::Subsample { lo: 1, hi: 4, scale: 2.0 },
    );
    assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    // tiling every site at scale 1 recovers the full likelihood
    let lik = typed_logp(&m, &tvi, &theta, Context::Likelihood);
    let tiled: f64 = (0..7).map(site).sum();
    assert!((tiled - lik).abs() < 1e-9, "{tiled} vs {lik}");
}

/// The expected subsampled gradient over all blocks equals the full-data
/// gradient at a fixed point — exactly, not just in distribution. Checked
/// on the *plain* (non-window-aware) logreg body, so the windowing here is
/// entirely executor-side.
#[test]
fn minibatch_gradient_is_exactly_unbiased_over_blocks() {
    for (n, batch) in [(48usize, 16usize), (50, 16)] {
        let bm = logreg_n(11, n, 5);
        let m = bm.model.as_ref();
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let tvi = init_typed(m, &mut rng);
        let theta: Vec<f64> = (0..5).map(|i| 0.15 * (i as f64) - 0.3).collect();
        let (lp_full, g_full) = typed_grad_fused(m, &tvi, &theta, Context::Default);
        assert!(lp_full.is_finite());

        let n_blocks = n.div_ceil(batch);
        let mut g_avg = vec![0.0; 5];
        let mut lp_avg = 0.0;
        for k in 0..n_blocks {
            let ctx = Context::Subsample {
                lo: k * batch,
                hi: ((k + 1) * batch).min(n),
                scale: n_blocks as f64,
            };
            let (lp_k, g_k) = typed_grad_fused(m, &tvi, &theta, ctx);
            lp_avg += lp_k / n_blocks as f64;
            for (a, b) in g_avg.iter_mut().zip(&g_k) {
                *a += b / n_blocks as f64;
            }
        }
        assert!(
            (lp_avg - lp_full).abs() < 1e-9,
            "n={n}: E[subsampled logp] {lp_avg} vs full {lp_full}"
        );
        assert_grad_close(&format!("n={n} E[grad] vs full"), &g_avg, &g_full, 1e-10);
    }
}

/// Window-aware and full-visit bodies produce identical Subsample
/// gradients — `skip_obs` keeps the site indices aligned.
#[test]
fn window_aware_body_matches_plain_body_gradients() {
    let bm = logreg_tall_n(13, 96, 4);
    let tall = bm.model.as_ref();
    let plain = LogReg {
        x: match &bm.data[0] {
            DataInput::F64 { data, .. } => data.clone(),
            _ => unreachable!(),
        },
        y: match &bm.data[1] {
            DataInput::F64 { data, .. } => data.iter().map(|&v| v as i64).collect(),
            _ => unreachable!(),
        },
        d: 4,
    };
    let mut rng = Xoshiro256pp::seed_from_u64(13);
    let tvi = init_typed(tall, &mut rng);
    let theta = [0.2, -0.1, 0.4, -0.3];
    for ctx in [
        Context::Default,
        Context::Subsample { lo: 10, hi: 42, scale: 3.0 },
        Context::Subsample { lo: 80, hi: 96, scale: 6.0 },
    ] {
        let (lp_a, g_a) = typed_grad_fused(tall, &tvi, &theta, ctx);
        let (lp_b, g_b) = typed_grad_fused(&plain, &tvi, &theta, ctx);
        assert!((lp_a - lp_b).abs() < 1e-9, "{ctx:?}: {lp_a} vs {lp_b}");
        assert_grad_close(&format!("{ctx:?} tall vs plain"), &g_a, &g_b, 1e-10);
    }
}

/// ISSUE acceptance: fused-path evaluation under `Subsample` allocates
/// zero arena nodes for out-of-window observations (window-aware body).
#[test]
fn subsample_fused_path_allocates_zero_nodes_out_of_window() {
    let n = 256;
    let bm = logreg_tall_n(9, n, 4);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(9);
    let tvi = init_typed(m, &mut rng);
    let theta = [0.1, -0.2, 0.3, -0.1];
    let mut grad = vec![0.0; 4];

    // empty window: likelihood contributes nothing — not a single node
    let lp = typed_grad_fused_into(
        m,
        &tvi,
        &theta,
        Context::Subsample { lo: 0, hi: 0, scale: 1.0 },
        &mut grad,
    );
    assert_eq!(
        arena::last_stats().nodes,
        0,
        "empty window must build zero arena nodes"
    );
    let prior_ref = typed_logp(m, &tvi, &theta, Context::Prior);
    assert!((lp - prior_ref).abs() < 1e-12, "{lp} vs prior {prior_ref}");
    // IsoNormal(0,1) prior over Real coordinates: ∇ = −θ
    for (g, t) in grad.iter().zip(&theta) {
        assert!((g + t).abs() < 1e-12, "prior grad {g} vs {}", -t);
    }

    // a 16-row window costs ~16 rows of nodes; the full pass costs ~256
    let _ = typed_grad_fused_into(
        m,
        &tvi,
        &theta,
        Context::Subsample { lo: 32, hi: 48, scale: 16.0 },
        &mut grad,
    );
    let nodes_window = arena::last_stats().nodes;
    assert!(nodes_window > 0);
    let _ = typed_grad_fused_into(m, &tvi, &theta, Context::Default, &mut grad);
    let nodes_full = arena::last_stats().nodes;
    assert!(
        nodes_full > 8 * nodes_window,
        "full pass {nodes_full} nodes vs 16/256 window {nodes_window}"
    );
}

/// Regression (prior-poisoning): an impossible observation must not
/// reject a prior-only evaluation on any executor path.
#[test]
fn impossible_observation_does_not_poison_prior_evaluations() {
    model! {
        pub ImpossibleObs { dummy: f64, }
        fn body<T>(this, api) {
            let _ = this.dummy;
            let m = tilde!(api, m ~ Normal(c(0.0), c(1.0)));
            let _ = m;
            // y = −1 is outside Exponential support: logpdf = −∞
            obs!(api, -1.0 => Exponential(c(1.0)));
        }
    }
    let m = ImpossibleObs { dummy: 0.0 };
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let vi = init_trace(&m, &mut rng);
    let tvi = TypedVarInfo::from_untyped(&vi);
    let theta = [0.4];
    let prior_lp = Normal::new(0.0, 1.0).logpdf(0.4);

    // the joint is genuinely impossible…
    assert_eq!(
        typed_logp(&m, &tvi, &theta, Context::Default),
        f64::NEG_INFINITY
    );
    // …but prior-only evaluations must stay finite on every path
    let lp_typed = typed_logp(&m, &tvi, &theta, Context::Prior);
    assert!((lp_typed - prior_lp).abs() < 1e-12, "{lp_typed}");
    let lp_untyped = untyped_logp(&m, &vi, &theta, Context::Prior);
    assert!((lp_untyped - prior_lp).abs() < 1e-12, "{lp_untyped}");
    for (label, (lp, g)) in [
        ("typed-fused", typed_grad_fused(&m, &tvi, &theta, Context::Prior)),
        ("untyped-fused", untyped_grad_fused(&m, &vi, &theta, Context::Prior)),
        ("typed-forward", typed_grad_forward(&m, &tvi, &theta, Context::Prior)),
        ("typed-reverse", typed_grad_reverse(&m, &tvi, &theta, Context::Prior)),
    ] {
        assert!(
            (lp - prior_lp).abs() < 1e-12,
            "{label}: prior logp {lp} vs {prior_lp}"
        );
        assert!((g[0] + 0.4).abs() < 1e-9, "{label}: prior grad {}", g[0]);
    }
    // out-of-window impossible observations are equally harmless
    let lp_win = typed_logp(
        &m,
        &tvi,
        &theta,
        Context::Subsample { lo: 1, hi: 2, scale: 1.0 },
    );
    assert!((lp_win - prior_lp).abs() < 1e-12, "{lp_win}");
}

/// ISSUE acceptance: on a tall logistic regression, minibatched ADVI
/// reaches the full-data fit's posterior means within 5% at strictly
/// lower wall-clock per iteration.
#[test]
fn minibatch_advi_matches_full_data_fit_on_tall_logreg() {
    let bm = logreg_tall_n(21, 4000, 4);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(21);
    let tvi = init_typed(m, &mut rng);
    let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();
    let ld = dynamicppl::gradient::NativeDensity::fused(m, &tvi);
    let advi = Advi {
        family: ViFamily::MeanField,
        max_iters: 2500,
        eval_every: 100,
        grad_samples: 4,
        elbo_samples: 100,
        tol_rel: 0.003,
        ..Advi::default()
    };

    let mut full_rng = Xoshiro256pp::seed_from_u64(22);
    let full = advi.fit(&ld, &theta0, &mut full_rng);
    assert!(full.elbo.is_finite());
    assert!(full.minibatch.is_none());

    let target = MinibatchTarget::new(m, &tvi, 256, Backend::ReverseFused);
    assert_eq!(target.n_obs, 4000);
    assert_eq!(target.n_blocks(), 4000 / 256 + 1);
    let mut mb_rng = Xoshiro256pp::seed_from_u64(23);
    let mb = advi.fit_minibatch(&target, &theta0, &mut mb_rng);
    assert!(mb.elbo.is_finite());
    assert_eq!(mb.minibatch, Some(256));
    assert!(!mb.eta_search_failed);

    // posterior means within 5% of the full-data fit (w is Real-domain,
    // so μ of q is the posterior-mean estimate directly)
    for i in 0..4 {
        let (a, b) = (mb.approx.mu()[i], full.approx.mu()[i]);
        assert!(
            (a - b).abs() < 0.05 * (1.0 + b.abs()),
            "mu[{i}]: minibatch {a} vs full {b}"
        );
    }
    // the two ELBOs agree to a few nats (same family, same target)
    assert!(
        (mb.elbo - full.elbo).abs() < 0.01 * full.elbo.abs() + 5.0,
        "elbo: minibatch {} vs full {}",
        mb.elbo,
        full.elbo
    );
    // strictly lower wall-clock per iteration: each minibatch step
    // touches 256 of 4000 rows
    let full_spi = full.opt_wall_secs / full.iters.max(1) as f64;
    let mb_spi = mb.opt_wall_secs / mb.iters.max(1) as f64;
    assert!(
        mb_spi < full_spi,
        "secs/iter: minibatch {mb_spi} vs full {full_spi}"
    );
}

/// Seeded minibatch fits are bit-deterministic (block resampling included).
#[test]
fn minibatch_fit_is_bit_deterministic() {
    let bm = logreg_tall_n(5, 600, 3);
    let m = bm.model.as_ref();
    let mut rng = Xoshiro256pp::seed_from_u64(5);
    let tvi = init_typed(m, &mut rng);
    let theta0 = vec![0.0; 3];
    let advi = Advi {
        max_iters: 120,
        eval_every: 40,
        grad_samples: 2,
        elbo_samples: 20,
        adapt_iters: 10,
        ..Advi::default()
    };
    let target = MinibatchTarget::new(m, &tvi, 64, Backend::ReverseFused);
    let run = || {
        let mut r = Xoshiro256pp::seed_from_u64(77);
        advi.fit_minibatch(&target, &theta0, &mut r)
    };
    let (a, b) = (run(), run());
    assert_eq!(a.eta, b.eta);
    assert_eq!(a.elbo.to_bits(), b.elbo.to_bits());
    for (x, y) in a.approx.params.iter().zip(&b.approx.params) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
