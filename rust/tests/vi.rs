//! End-to-end tests of the ADVI subsystem: posterior recovery against
//! analytic (conjugate / Kalman) posteriors for both families, ELBO
//! trajectory shape, the full-rank ≥ mean-field ordering on a correlated
//! target, bit-determinism of a seeded fit, and the chain/query
//! integration (posterior predictive over a chain of approximation
//! draws).

use dynamicppl::coordinator::query_registry;
use dynamicppl::gradient::{FnDensity, NativeDensity};
use dynamicppl::inference::{sample_chain, Nuts, SamplerKind};
use dynamicppl::model::init_typed;
use dynamicppl::models::gauss::gauss_unknown_n;
use dynamicppl::prelude::*;
use dynamicppl::query::{eval_query, Query};
use dynamicppl::util::stats;
use dynamicppl::vi::{Advi, ViFamily};

/// A thorough fit configuration for the recovery tests (the defaults are
/// tuned for speed; posterior-recovery assertions at the 5% level want a
/// longer, tighter optimization).
fn thorough(family: ViFamily) -> Advi {
    Advi {
        family,
        max_iters: 5000,
        eval_every: 100,
        grad_samples: 8,
        elbo_samples: 200,
        tol_rel: 0.001,
        ..Advi::default()
    }
}

/// Normal–InverseGamma conjugate posterior of `GaussUnknown`
/// (s ~ InvGamma(2, 3); m | s ~ N(0, √s); y_i ~ N(m, √s)):
/// returns (E[m], sd[m], E[s], sd[s]).
fn nig_posterior(y: &[f64]) -> (f64, f64, f64, f64) {
    let (a0, b0, k0) = (2.0, 3.0, 1.0);
    let n = y.len() as f64;
    let ybar = stats::mean(y);
    let ss: f64 = y.iter().map(|v| (v - ybar) * (v - ybar)).sum();
    let kn = k0 + n;
    let mu_n = n * ybar / kn;
    let an = a0 + 0.5 * n;
    let bn = b0 + 0.5 * ss + 0.5 * k0 * n * ybar * ybar / kn;
    let e_s = bn / (an - 1.0);
    let sd_s = bn / ((an - 1.0) * (an - 2.0).sqrt());
    let sd_m = (bn / ((an - 1.0) * kn)).sqrt();
    (mu_n, sd_m, e_s, sd_s)
}

/// Fit ADVI on `gauss_unknown` and return the chain of approximation
/// draws built by the ordinary `sample_chain` driver.
fn fit_gauss_chain(family: ViFamily, draws: usize, seed: u64) -> dynamicppl::chain::Chain {
    let bm = gauss_unknown_n(1, 200);
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    sample_chain(
        &ld,
        &tvi,
        &SamplerKind::Advi(thorough(family)),
        0,
        draws,
        seed,
    )
}

#[test]
fn advi_recovers_gauss_unknown_analytic_posterior_both_families() {
    let bm = gauss_unknown_n(1, 200);
    let y = match &bm.data[0] {
        dynamicppl::runtime::DataInput::F64 { data, .. } => data.clone(),
        _ => unreachable!(),
    };
    let (e_m, sd_m, e_s, sd_s) = nig_posterior(&y);
    for family in [ViFamily::MeanField, ViFamily::FullRank] {
        let chain = fit_gauss_chain(family, 8000, 31);
        let label = family.label();
        let (m_hat, m_sd_hat) = (chain.mean("m").unwrap(), chain.std("m").unwrap());
        let (s_hat, s_sd_hat) = (chain.mean("s").unwrap(), chain.std("s").unwrap());
        // ISSUE acceptance: means and sds within 5% of the analytic values
        assert!(
            (m_hat - e_m).abs() / e_m.abs() < 0.05,
            "{label}: E[m] {m_hat} vs {e_m}"
        );
        assert!(
            (s_hat - e_s).abs() / e_s < 0.05,
            "{label}: E[s] {s_hat} vs {e_s}"
        );
        assert!(
            (m_sd_hat - sd_m).abs() / sd_m < 0.05,
            "{label}: sd[m] {m_sd_hat} vs {sd_m}"
        );
        assert!(
            (s_sd_hat - sd_s).abs() / sd_s < 0.07,
            "{label}: sd[s] {s_sd_hat} vs {sd_s} (small lognormal-vs-invgamma shape gap)"
        );
        // the ELBO lower-bounds the evidence and is finite
        assert!(chain.stats.log_evidence.is_finite());
    }
}

model! {
    /// Linear-Gaussian state space (Kalman ground truth): h_0 ~ N(0,1);
    /// h_t ~ N(φ·h_{t−1}, q); y_t ~ N(h_t, r).
    pub LinSsmVi {
        y: Vec<f64>,
        phi: f64,
        q: f64,
        r: f64,
    }
    fn body<T>(this, api) {
        let mut h_prev = tilde!(api, h[0] ~ Normal(c(0.0), c(1.0)));
        obs!(api, this.y[0] => Normal(h_prev, c(this.r)));
        for t in 1..this.y.len() {
            let h_t = tilde!(api, h[t] ~ Normal(h_prev * this.phi, c(this.q)));
            obs!(api, this.y[t] => Normal(h_t, c(this.r)));
            h_prev = h_t;
        }
    }
}

/// Kalman filter + RTS smoother means for [`LinSsmVi`] — the exact
/// posterior marginals the Gaussian posterior makes available.
fn kalman_smoother_means(y: &[f64], phi: f64, q: f64, r: f64) -> Vec<f64> {
    let t_len = y.len();
    let (q2, r2) = (q * q, r * r);
    let mut mf = Vec::with_capacity(t_len);
    let mut pf = Vec::with_capacity(t_len);
    let mut mp = Vec::with_capacity(t_len);
    let mut pp = Vec::with_capacity(t_len);
    for t in 0..t_len {
        let (m_pred, p_pred) = if t == 0 {
            (0.0, 1.0)
        } else {
            (phi * mf[t - 1], phi * phi * pf[t - 1] + q2)
        };
        mp.push(m_pred);
        pp.push(p_pred);
        let s = p_pred + r2;
        let k = p_pred / s;
        mf.push(m_pred + k * (y[t] - m_pred));
        pf.push((1.0 - k) * p_pred);
    }
    let mut ms = vec![0.0; t_len];
    ms[t_len - 1] = mf[t_len - 1];
    for t in (0..t_len - 1).rev() {
        let c = pf[t] * phi / pp[t + 1];
        ms[t] = mf[t] + c * (ms[t + 1] - mp[t + 1]);
    }
    ms
}

#[test]
fn advi_recovers_kalman_smoother_marginal_means() {
    // The posterior of a linear-Gaussian SSM is exactly Gaussian, so both
    // families recover the smoother means (the means of a Gaussian target
    // are exact at the mean-field optimum too; only variances differ).
    let (phi, q, r) = (0.9, 0.4, 0.5);
    let mut rng = Xoshiro256pp::seed_from_u64(77);
    let mut y = Vec::new();
    let mut h = 0.0;
    for t in 0..12 {
        h = if t == 0 { rng.normal() } else { phi * h + q * rng.normal() };
        y.push(h + r * rng.normal());
    }
    let truth = kalman_smoother_means(&y, phi, q, r);
    let m = LinSsmVi { y, phi, q, r };
    let tvi = init_typed(&m, &mut rng);
    let ld = NativeDensity::fused(&m, &tvi);
    let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();
    for family in [ViFamily::MeanField, ViFamily::FullRank] {
        let mut fit_rng = Xoshiro256pp::seed_from_u64(78);
        let fit = thorough(family).fit(&ld, &theta0, &mut fit_rng);
        // h is unconstrained (Real domain): μ of q is the posterior mean
        for (t, &want) in truth.iter().enumerate() {
            let got = fit.approx.mu()[t];
            assert!(
                (got - want).abs() < 0.12,
                "{}: h[{t}] mean {got} vs smoother {want}",
                family.label()
            );
        }
    }
}

#[test]
fn elbo_is_monotone_to_plateau_under_fixed_seed() {
    let bm = gauss_unknown_n(1, 200);
    let mut rng = Xoshiro256pp::seed_from_u64(19);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();
    let mut fit_rng = Xoshiro256pp::seed_from_u64(20);
    let fit = thorough(ViFamily::MeanField).fit(&ld, &theta0, &mut fit_rng);
    assert!(fit.elbo_trace.len() >= 3, "{:?}", fit.elbo_trace);
    let first = fit.elbo_trace.first().unwrap().1;
    let last = fit.elbo_trace.last().unwrap().1;
    // net improvement from the first evaluation …
    assert!(last > first, "ELBO fell: {first} → {last}");
    // … and a plateau at the end: the last two evaluations agree to
    // within noise (the convergence monitor's own criterion)
    let k = fit.elbo_trace.len();
    let tail_delta = (fit.elbo_trace[k - 1].1 - fit.elbo_trace[k - 2].1).abs();
    assert!(
        tail_delta < 0.01 * last.abs().max(1.0) + 4.0 * fit.elbo_se,
        "no plateau: tail Δ = {tail_delta}, se = {}",
        fit.elbo_se
    );
    assert!(fit.converged, "fit did not converge within budget");
}

#[test]
fn fullrank_elbo_beats_meanfield_on_correlated_posterior() {
    // N(0, Σ) with ρ = 0.9: the mean-field optimum pays
    // ½·ln(1−ρ²) ≈ −0.83 nats of ELBO that full-rank recovers.
    let rho: f64 = 0.9;
    let det = 1.0 - rho * rho;
    let make = || FnDensity {
        dim: 2,
        f: move |t: &[f64]| {
            -0.5 * (t[0] * t[0] - 2.0 * rho * t[0] * t[1] + t[1] * t[1]) / det
                - 0.5 * det.ln()
                - dynamicppl::util::math::LN_2PI
        },
        g: move |t: &[f64]| {
            (
                -0.5 * (t[0] * t[0] - 2.0 * rho * t[0] * t[1] + t[1] * t[1]) / det
                    - 0.5 * det.ln()
                    - dynamicppl::util::math::LN_2PI,
                vec![-(t[0] - rho * t[1]) / det, -(t[1] - rho * t[0]) / det],
            )
        },
    };
    let ld = make();
    let fit_family = |family: ViFamily| {
        let mut rng = Xoshiro256pp::seed_from_u64(41);
        thorough(family).fit(&ld, &[0.5, -0.5], &mut rng)
    };
    let mf = fit_family(ViFamily::MeanField);
    let fr = fit_family(ViFamily::FullRank);
    assert!(mf.elbo.is_finite() && fr.elbo.is_finite());
    assert!(
        fr.elbo > mf.elbo + 0.3,
        "full-rank {} should beat mean-field {} by ≈ 0.83 nats",
        fr.elbo,
        mf.elbo
    );
    // full-rank of an exact-family target reaches the true evidence (0)
    assert!(fr.elbo.abs() < 0.25, "{}", fr.elbo);
    assert!(
        (mf.elbo - 0.5 * det.ln()).abs() < 0.3,
        "mean-field ELBO {} vs analytic optimum {}",
        mf.elbo,
        0.5 * det.ln()
    );
}

#[test]
fn seeded_fit_is_bit_deterministic_end_to_end() {
    let a = fit_gauss_chain(ViFamily::FullRank, 100, 91);
    let b = fit_gauss_chain(ViFamily::FullRank, 100, 91);
    assert_eq!(a.len(), b.len());
    assert_eq!(
        a.stats.log_evidence.to_bits(),
        b.stats.log_evidence.to_bits(),
        "ELBO must be bit-identical under a fixed seed"
    );
    for (ra, rb) in a.rows().iter().zip(b.rows()) {
        for (x, y) in ra.iter().zip(rb) {
            assert_eq!(x.to_bits(), y.to_bits(), "draws must be bit-identical");
        }
    }
    for (x, y) in a.logp.iter().zip(&b.logp) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn vi_chain_drives_posterior_predictive_queries_like_nuts() {
    // The paper's `prob"y | chain"` machinery must work unchanged over a
    // chain of approximation draws: compare the VI-chain posterior
    // predictive against a NUTS-chain reference on the same model/data.
    let vi_chain = fit_gauss_chain(ViFamily::MeanField, 4000, 61);
    let bm = gauss_unknown_n(1, 200);
    let mut rng = Xoshiro256pp::seed_from_u64(62);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    let ld = NativeDensity::fused(bm.model.as_ref(), &tvi);
    let nuts_chain = sample_chain(
        &ld,
        &tvi,
        &SamplerKind::Nuts(Nuts::default()),
        500,
        4000,
        63,
    );
    let q = Query::parse("y = 1.4 | chain, model = gauss_unknown").unwrap();
    let reg = query_registry();
    let vi = eval_query(&q, &reg, Some(&vi_chain)).unwrap();
    let nuts = eval_query(&q, &reg, Some(&nuts_chain)).unwrap();
    assert!(vi.log_prob.is_finite() && nuts.log_prob.is_finite());
    assert!(
        (vi.log_prob - nuts.log_prob).abs() < 0.1,
        "posterior predictive: VI {} vs NUTS {}",
        vi.log_prob,
        nuts.log_prob
    );
}
