//! Three-layer consistency: for every Table-1 model, the AOT-compiled XLA
//! artifact (L2/L1, built by `make artifacts`) must compute the same
//! log-density as the Rust typed executor (L3) at the same unconstrained
//! point — and its gradient must match the Rust reverse-mode tape.
//!
//! These tests are skipped (with a message) when `artifacts/` has not been
//! built; run `make artifacts` first.

use dynamicppl::context::Context;
use dynamicppl::gradient::LogDensity;
use dynamicppl::model::{init_typed, typed_grad_reverse, typed_logp};
use dynamicppl::models::{build, ALL_MODELS};
use dynamicppl::runtime::{artifact_exists, artifacts_dir, XlaDensity};
use dynamicppl::util::rng::Xoshiro256pp;

fn check_model(name: &str, grad_rtol: f64) {
    if !artifact_exists(name) {
        eprintln!("SKIP {name}: artifact missing (run `make artifacts`)");
        return;
    }
    let bm = build(name, 42);
    let xla = XlaDensity::load(&artifacts_dir(), name, bm.theta_dim, &bm.data)
        .unwrap_or_else(|e| panic!("{name}: {e:?}"));

    let mut rng = Xoshiro256pp::seed_from_u64(42);
    let tvi = init_typed(bm.model.as_ref(), &mut rng);
    assert_eq!(tvi.dim(), bm.theta_dim, "{name}: layout dim");

    // three test points: the prior draw, a perturbation, and a "cold" point
    let base = tvi.unconstrained.clone();
    let points: Vec<Vec<f64>> = vec![
        base.clone(),
        base.iter().map(|x| x * 0.5 + 0.1).collect(),
        base.iter().map(|_| -0.2).collect(),
    ];

    for (pi, theta) in points.iter().enumerate() {
        let lp_rust = typed_logp(bm.model.as_ref(), &tvi, theta, Context::Default);
        let (lp_xla, grad_xla) = xla.logp_grad(theta);
        let denom = 1.0 + lp_rust.abs();
        assert!(
            ((lp_rust - lp_xla) / denom).abs() < 1e-9,
            "{name} point {pi}: rust logp {lp_rust} vs xla {lp_xla}"
        );
        // gradient vs the Rust tape
        let (_, grad_rust) = typed_grad_reverse(bm.model.as_ref(), &tvi, theta, Context::Default);
        for i in 0..theta.len() {
            let scale = 1.0 + grad_rust[i].abs();
            assert!(
                ((grad_rust[i] - grad_xla[i]) / scale).abs() < grad_rtol,
                "{name} point {pi} grad[{i}]: rust {} vs xla {}",
                grad_rust[i],
                grad_xla[i]
            );
        }
    }
}

#[test]
fn gaussian_10kd_xla_matches_rust() {
    check_model("gaussian_10kd", 1e-8);
}

#[test]
fn gauss_unknown_xla_matches_rust() {
    check_model("gauss_unknown", 1e-8);
}

#[test]
fn naive_bayes_xla_matches_rust() {
    check_model("naive_bayes", 1e-8);
}

#[test]
fn logreg_xla_matches_rust() {
    check_model("logreg", 1e-8);
}

#[test]
fn hier_poisson_xla_matches_rust() {
    check_model("hier_poisson", 1e-8);
}

#[test]
fn sto_volatility_xla_matches_rust() {
    check_model("sto_volatility", 1e-8);
}

#[test]
fn hmm_semisup_xla_matches_rust() {
    check_model("hmm_semisup", 1e-7);
}

#[test]
fn lda_xla_matches_rust() {
    check_model("lda", 1e-7);
}

/// The Pallas validation artifact (interpret-mode kernels) must agree with
/// the fused-jnp runtime artifact — i.e. the L1 kernel schedule computes
/// the same numbers as its oracle *through the whole AOT pipeline*.
#[test]
fn pallas_artifacts_match_runtime_artifacts() {
    for name in ["gauss_unknown", "logreg"] {
        let pallas_path = artifacts_dir().join(format!("{name}.pallas.hlo.txt"));
        if !artifact_exists(name) || !pallas_path.exists() {
            eprintln!("SKIP {name}: artifacts missing");
            continue;
        }
        let bm = build(name, 42);
        let runtime_art = XlaDensity::load(&artifacts_dir(), name, bm.theta_dim, &bm.data)
            .unwrap();
        // load the pallas variant by renaming through a temp dir view
        let tmp = std::env::temp_dir().join(format!("dppl_pallas_{name}_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::copy(&pallas_path, tmp.join(format!("{name}.vg.hlo.txt"))).unwrap();
        let pallas_art = XlaDensity::load(&tmp, name, bm.theta_dim, &bm.data).unwrap();

        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.4 + 0.05).collect();
        let (lp_r, g_r) = runtime_art.logp_grad(&theta);
        let (lp_p, g_p) = pallas_art.logp_grad(&theta);
        assert!(
            ((lp_r - lp_p) / (1.0 + lp_r.abs())).abs() < 1e-10,
            "{name}: jnp {lp_r} vs pallas {lp_p}"
        );
        for i in 0..g_r.len() {
            assert!(
                ((g_r[i] - g_p[i]) / (1.0 + g_r[i].abs())).abs() < 1e-9,
                "{name} grad[{i}]"
            );
        }
        let _ = std::fs::remove_dir_all(&tmp);
    }
}

/// The fused trajectory artifact must reproduce the unfused sampler's
/// chain draw-for-draw (same RNG stream, identity mass, fixed ε).
#[test]
fn fused_trajectory_matches_unfused_hmc() {
    use dynamicppl::inference::hmc::HmcFusedXla;
    use dynamicppl::inference::Hmc;
    use dynamicppl::runtime::XlaTrajectory;

    for name in ["gauss_unknown", "hier_poisson"] {
        if !artifact_exists(name) || !XlaTrajectory::traj_artifact_exists(name) {
            eprintln!("SKIP {name}: artifacts missing");
            continue;
        }
        let bm = build(name, 42);
        let vg = XlaDensity::load(&artifacts_dir(), name, bm.theta_dim, &bm.data).unwrap();
        let traj = XlaTrajectory::load(&artifacts_dir(), name, bm.theta_dim, &bm.data).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let tvi = init_typed(bm.model.as_ref(), &mut rng);
        let theta0: Vec<f64> = tvi.unconstrained.iter().map(|x| x * 0.1).collect();

        let mut rng1 = Xoshiro256pp::seed_from_u64(7);
        let unfused = Hmc::paper(bm.step_size).sample(&vg, &theta0, 0, 30, &mut rng1);
        let mut rng2 = Xoshiro256pp::seed_from_u64(7);
        let fused = HmcFusedXla {
            traj: &traj,
            vg: &vg,
            step_size: bm.step_size,
        }
        .sample(&theta0, 0, 30, &mut rng2);

        assert_eq!(unfused.thetas.len(), fused.thetas.len());
        for (i, (a, b)) in unfused.thetas.iter().zip(&fused.thetas).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert!(
                    (x - y).abs() < 1e-8,
                    "{name} draw {i}: {x} vs {y} (fused/unfused diverged)"
                );
            }
        }
    }
}

#[test]
fn all_artifacts_in_manifest() {
    let manifest = artifacts_dir().join("manifest.txt");
    if !manifest.exists() {
        eprintln!("SKIP: no manifest (run `make artifacts`)");
        return;
    }
    let text = std::fs::read_to_string(manifest).unwrap();
    for name in ALL_MODELS {
        assert!(text.contains(&format!("model={name} ")), "{name} missing");
    }
}
