//! Posterior-serving integration tests: the artifact cache must hit and
//! evict correctly, cached queries must agree with closed-form conjugate
//! answers, streaming updates must match from-scratch refits (posterior
//! means within MC error, evidence increments telescoping to the batch
//! value), the ESS-collapse fallback must fire, seeded update sequences
//! must replay bit-identically, the TCP protocol must round-trip, and the
//! shared compile cell must promote exactly once under concurrent first
//! evaluations.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Barrier};

use dynamicppl::gradient::{LogDensity, NativeDensity};
use dynamicppl::model::init_typed;
use dynamicppl::obs::metrics::{self, Counter};
use dynamicppl::prelude::*;
use dynamicppl::serve::query::ServeQuery;
use dynamicppl::serve::server::{dispatch, Server};
use dynamicppl::serve::update::UpdateKind;
use dynamicppl::serve::{
    conjugate_log_evidence, kalman_oracle, simulate_kalman, FitSpec, ServeConfig, ServeHandle,
    StreamNormal,
};
use dynamicppl::util::json::Json;

/// Closed-form posterior (mean, var) of the [`StreamNormal`] conjugate
/// stream: prior `m ~ N(0, 1)`, likelihood `y_t ~ N(m, 1)`.
fn conjugate_posterior(y: &[f64]) -> (f64, f64) {
    let n = y.len() as f64;
    (y.iter().sum::<f64>() / (n + 1.0), 1.0 / (n + 1.0))
}

fn normal_stream(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    (0..n).map(|_| 0.7 + rng.normal()).collect()
}

// ------------------------------------------------------------- the cache

#[test]
fn cache_hits_misses_evicts_and_invalidates() {
    let handle = ServeHandle::new(ServeConfig {
        cache_capacity: 2,
        ..ServeConfig::default()
    });
    handle
        .init_stream("normal_normal", normal_stream(8, 1))
        .unwrap();
    let spec = FitSpec::smc(32, 5);

    let (_, cached) = handle.fit("normal_normal", &spec).unwrap();
    assert!(!cached, "first fit must miss");
    let (_, cached) = handle.fit("normal_normal", &spec).unwrap();
    assert!(cached, "second fit must hit");

    // distinct sampler configs are distinct artifacts; capacity 2 evicts
    let spec2 = FitSpec::smc(32, 6);
    let spec3 = FitSpec::smc(32, 7);
    handle.fit("normal_normal", &spec2).unwrap();
    handle.fit("normal_normal", &spec3).unwrap();
    let stats = handle.stats();
    assert!(stats.artifacts <= 2, "capacity 2 held {}", stats.artifacts);
    assert!(stats.evictions >= 1, "third artifact must evict");
    assert!(stats.cache_hits >= 1);
    assert!(stats.cache_misses >= 3);

    // explicit invalidation drops everything for the model…
    assert!(handle.invalidate("normal_normal") >= 1);
    assert_eq!(handle.stats().artifacts, 0);
    // …and so does re-initializing the stream (data changed)
    handle.fit("normal_normal", &spec).unwrap();
    handle
        .init_stream("normal_normal", normal_stream(8, 2))
        .unwrap();
    assert_eq!(handle.stats().artifacts, 0, "init must drop stale fits");
}

#[test]
fn unknown_models_and_empty_streams_are_rejected() {
    let handle = ServeHandle::new(ServeConfig::default());
    assert!(handle.init_stream("nope", vec![1.0]).is_err());
    assert!(handle.init_stream("kalman", vec![]).is_err());
    assert!(handle
        .fit("normal_normal", &FitSpec::default())
        .is_err_and(|e| e.contains("init")));
}

// ----------------------------------------------------------- the queries

#[test]
fn cached_queries_agree_with_the_conjugate_posterior() {
    let y = normal_stream(6, 3);
    let (mu_n, v_n) = conjugate_posterior(&y);
    let handle = ServeHandle::new(ServeConfig::default());
    handle.init_stream("normal_normal", y.clone()).unwrap();
    let spec = FitSpec::smc(2048, 11);
    let q = |q: &ServeQuery| handle.query("normal_normal", &spec, q).unwrap();

    let mean = q(&ServeQuery::Mean { param: "m".into() });
    assert!((mean - mu_n).abs() < 0.1, "mean {mean} vs {mu_n}");
    let std = q(&ServeQuery::Std { param: "m".into() });
    assert!((std - v_n.sqrt()).abs() < 0.1, "std {std} vs {}", v_n.sqrt());
    let med = q(&ServeQuery::Quantile {
        param: "m".into(),
        q: 0.5,
    });
    assert!((med - mu_n).abs() < 0.2, "median {med} vs {mu_n}");
    let lz = q(&ServeQuery::Evidence);
    let lz_exact = conjugate_log_evidence(&y);
    assert!((lz - lz_exact).abs() < 0.5, "evidence {lz} vs {lz_exact}");

    // posterior predictive of one held-out point: N(mu_n, 1 + v_n)
    let y_star = 0.9;
    let lp = q(&ServeQuery::LogPredictive { y: vec![y_star] });
    let exact = dynamicppl::dist::Normal::new(mu_n, (1.0 + v_n).sqrt()).logpdf(y_star);
    assert!((lp - exact).abs() < 0.15, "predictive {lp} vs {exact}");

    // a bad quantile and a missing param surface as errors, not panics
    assert!(handle
        .query(
            "normal_normal",
            &spec,
            &ServeQuery::Quantile {
                param: "m".into(),
                q: 1.5
            }
        )
        .is_err());
    assert!(handle
        .query("normal_normal", &spec, &ServeQuery::Mean { param: "zz".into() })
        .is_err());
}

#[test]
fn batched_predictive_matches_one_by_one_queries() {
    let handle = ServeHandle::new(ServeConfig::default());
    handle
        .init_stream("normal_normal", normal_stream(10, 4))
        .unwrap();
    let spec = FitSpec::smc(256, 13);
    let ys: Vec<Vec<f64>> = vec![vec![0.2], vec![-0.4, 0.5], vec![1.1, 0.0, 0.3]];
    let batch = handle.predictive_batch("normal_normal", &spec, &ys).unwrap();
    assert_eq!(batch.len(), ys.len());
    for (y, b) in ys.iter().zip(&batch) {
        let one = handle
            .query(
                "normal_normal",
                &spec,
                &ServeQuery::LogPredictive { y: y.clone() },
            )
            .unwrap();
        assert!(
            (one - b).abs() < 1e-12,
            "batch {b} vs single {one} for {y:?}"
        );
    }
}

// -------------------------------------------------------------- updates

#[test]
fn streaming_updates_agree_with_batch_refit_on_the_conjugate_stream() {
    let all = normal_stream(24, 7);
    let handle = ServeHandle::new(ServeConfig::default());
    handle.init_stream("normal_normal", all[..12].to_vec()).unwrap();
    let spec = FitSpec::smc(1024, 17);
    let (first, _) = handle.fit("normal_normal", &spec).unwrap();
    let z0 = first.chain.stats.log_evidence;

    let mut increments = Vec::new();
    let mut last_evidence = z0;
    for batch in all[12..].chunks(4) {
        let rep = handle.update_stream("normal_normal", batch, &spec).unwrap();
        assert_eq!(rep.kind, UpdateKind::Streamed, "conjugate stream must stay cheap");
        increments.push(rep.increment);
        last_evidence = rep.log_evidence;
    }

    // increments telescope exactly to the final running evidence…
    let total = z0 + increments.iter().sum::<f64>();
    assert!(
        (total - last_evidence).abs() < 1e-9,
        "telescoping broke: {total} vs {last_evidence}"
    );
    // …which estimates the closed-form batch evidence of the full record
    let lz_exact = conjugate_log_evidence(&all);
    assert!(
        (last_evidence - lz_exact).abs() < 1.0,
        "evidence {last_evidence} vs exact {lz_exact}"
    );

    // streamed and refit posteriors agree with the conjugate mean
    let (mu_n, _) = conjugate_posterior(&all);
    let streamed = handle
        .query("normal_normal", &spec, &ServeQuery::Mean { param: "m".into() })
        .unwrap();
    assert!((streamed - mu_n).abs() < 0.2, "streamed {streamed} vs {mu_n}");

    let refit_handle = ServeHandle::new(ServeConfig::default());
    refit_handle.init_stream("normal_normal", all.clone()).unwrap();
    let refit = refit_handle
        .query("normal_normal", &spec, &ServeQuery::Mean { param: "m".into() })
        .unwrap();
    assert!((refit - mu_n).abs() < 0.2, "refit {refit} vs {mu_n}");
}

#[test]
fn streaming_updates_track_the_kalman_oracle() {
    // the dynamic-structure path: each appended step introduces a fresh
    // latent h[t], demoting the resumed cloud to boxed execution
    let all = simulate_kalman(38, 23);
    let (ll_exact, smoothed) = kalman_oracle(&all);
    let handle = ServeHandle::new(ServeConfig::default());
    handle.init_stream("kalman", all[..30].to_vec()).unwrap();
    let spec = FitSpec::smc(512, 29);
    handle.fit("kalman", &spec).unwrap();

    let rep = handle.update_stream("kalman", &all[30..], &spec).unwrap();
    assert_eq!(rep.kind, UpdateKind::Streamed);
    assert_eq!(rep.n_obs, all.len());
    assert!(
        (rep.log_evidence - ll_exact).abs() < 2.0,
        "evidence {} vs Kalman ll {ll_exact}",
        rep.log_evidence
    );

    // the final-state posterior mean is a filtering estimate — the part
    // of the path a particle filter estimates well
    let last = format!("h[{}]", all.len() - 1);
    let streamed = handle
        .query("kalman", &spec, &ServeQuery::Mean { param: last.clone() })
        .unwrap();
    let oracle = smoothed[all.len() - 1];
    assert!((streamed - oracle).abs() < 0.35, "streamed {streamed} vs {oracle}");

    let refit_handle = ServeHandle::new(ServeConfig::default());
    refit_handle.init_stream("kalman", all.clone()).unwrap();
    let refit = refit_handle
        .query("kalman", &spec, &ServeQuery::Mean { param: last })
        .unwrap();
    assert!((refit - oracle).abs() < 0.35, "refit {refit} vs {oracle}");
}

#[test]
fn ess_collapse_falls_back_to_a_full_refit() {
    // refit_ess_frac = 2 is unreachable (ESS ≤ N), so every streaming
    // update must take the fallback
    let handle = ServeHandle::new(ServeConfig {
        refit_ess_frac: 2.0,
        ..ServeConfig::default()
    });
    handle
        .init_stream("normal_normal", normal_stream(10, 31))
        .unwrap();
    let spec = FitSpec::smc(128, 37);
    handle.fit("normal_normal", &spec).unwrap();
    let rep = handle
        .update_stream("normal_normal", &[0.4, -0.2], &spec)
        .unwrap();
    assert_eq!(rep.kind, UpdateKind::EssRefit);
    assert_eq!(rep.kind.label(), "ess-refit");
    let stats = handle.stats();
    assert_eq!(stats.ess_refits, 1);
    assert_eq!(stats.stream_updates, 0);
    // the refit artifact still answers queries
    assert!(handle
        .query("normal_normal", &spec, &ServeQuery::Mean { param: "m".into() })
        .unwrap()
        .is_finite());
}

#[test]
fn updates_without_a_cached_cloud_pay_batch_cost() {
    let handle = ServeHandle::new(ServeConfig::default());
    handle
        .init_stream("normal_normal", normal_stream(8, 41))
        .unwrap();
    // no fit first: nothing cached to resume
    let spec = FitSpec::smc(64, 43);
    let rep = handle
        .update_stream("normal_normal", &[0.1], &spec)
        .unwrap();
    assert_eq!(rep.kind, UpdateKind::EssRefit);
    assert_eq!(handle.stats().ess_refits, 1);
    // non-SMC posteriors cannot stream
    let nuts = FitSpec {
        sampler: "nuts".into(),
        ..FitSpec::default()
    };
    assert!(handle.update_stream("normal_normal", &[0.1], &nuts).is_err());
}

#[test]
fn seeded_update_sequences_replay_bit_identically() {
    let run = || {
        let handle = ServeHandle::new(ServeConfig::default());
        handle
            .init_stream("normal_normal", normal_stream(12, 47))
            .unwrap();
        let spec = FitSpec::smc(256, 53);
        handle.fit("normal_normal", &spec).unwrap();
        let r1 = handle
            .update_stream("normal_normal", &[0.5, -0.3, 0.8], &spec)
            .unwrap();
        let r2 = handle
            .update_stream("normal_normal", &[0.2, 0.9], &spec)
            .unwrap();
        let mean = handle
            .query("normal_normal", &spec, &ServeQuery::Mean { param: "m".into() })
            .unwrap();
        (
            r1.increment.to_bits(),
            r2.increment.to_bits(),
            r2.log_evidence.to_bits(),
            mean.to_bits(),
        )
    };
    assert_eq!(run(), run(), "a seeded update sequence must be deterministic");
}

// --------------------------------------------------------- the protocol

#[test]
fn dispatch_answers_and_survives_bad_requests() {
    let handle = ServeHandle::new(ServeConfig::default());
    let send = |line: &str| dispatch(&handle, &Json::parse(line).unwrap()).0;

    // errors come back as ok:false lines, never panics
    for bad in [
        "{\"kind\": \"mean\"}",                              // no op
        "{\"op\": \"frobnicate\"}",                          // unknown op
        "{\"op\": \"fit\"}",                                 // no model
        "{\"op\": \"init\", \"model\": \"nope\", \"y\": [1]}", // unknown model
        "{\"op\": \"query\", \"model\": \"normal_normal\", \"kind\": \"huh\"}",
    ] {
        let resp = Json::parse(&send(bad)).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false), "{bad}");
        assert!(resp.get("error").and_then(Json::as_str).is_some(), "{bad}");
    }

    let ok = send(
        "{\"op\": \"init\", \"model\": \"normal_normal\", \"y\": [0.3, -0.2, 0.5, 0.1]}",
    );
    let resp = Json::parse(&ok).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("version").and_then(Json::as_u64), Some(1));

    let resp = Json::parse(&send(
        "{\"op\": \"query\", \"model\": \"normal_normal\", \"kind\": \"mean\", \
         \"param\": \"m\", \"particles\": 64, \"seed\": 3}",
    ))
    .unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert!(resp.get("value").and_then(Json::as_f64).unwrap().is_finite());

    let (_, shutdown) = dispatch(&handle, &Json::parse("{\"op\": \"stats\"}").unwrap());
    assert!(!shutdown);
    let (_, shutdown) = dispatch(&handle, &Json::parse("{\"op\": \"shutdown\"}").unwrap());
    assert!(shutdown);
}

#[test]
fn tcp_server_round_trips_the_protocol() {
    let handle = Arc::new(ServeHandle::new(ServeConfig::default()));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&handle), 2).unwrap();
    let addr = server.local_addr().unwrap();
    let daemon = std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut ask = |line: &str| -> Json {
        writer.write_all(line.as_bytes()).unwrap();
        writer.write_all(b"\n").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response {resp:?}: {e}"))
    };

    let resp = ask("{\"op\": \"init\", \"model\": \"normal_normal\", \"y\": [0.4, 0.1, -0.3, 0.7]}");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));

    let resp = ask(
        "{\"op\": \"fit\", \"model\": \"normal_normal\", \"particles\": 64, \"seed\": 9}",
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("cached").and_then(Json::as_bool), Some(false));

    let resp = ask(
        "{\"op\": \"query\", \"model\": \"normal_normal\", \"kind\": \"quantile\", \
         \"param\": \"m\", \"q\": 0.5, \"particles\": 64, \"seed\": 9}",
    );
    assert!(resp.get("value").and_then(Json::as_f64).unwrap().is_finite());

    let resp = ask(
        "{\"op\": \"update\", \"model\": \"normal_normal\", \"y\": [0.2, 0.6], \
         \"particles\": 64, \"seed\": 9}",
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.get("n_obs").and_then(Json::as_u64), Some(6));

    let resp = ask("{\"op\": \"stats\"}");
    assert!(resp.get("queries").and_then(Json::as_u64).unwrap() >= 1);

    let resp = ask("{\"op\": \"shutdown\"}");
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
    daemon.join().unwrap().unwrap();

    // the in-process view agrees with what the wire reported
    assert!(handle.stats().stream_updates + handle.stats().ess_refits >= 1);
}

#[test]
fn concurrent_misses_fit_once_and_share_the_artifact() {
    // four threads race a cold fit of one key: single-flight elects one
    // leader, everyone else blocks on the claim (or hits the cache) and
    // serves the leader's Arc — one fit, one artifact, zero redundancy
    let handle = Arc::new(ServeHandle::new(ServeConfig::default()));
    handle
        .init_stream("normal_normal", normal_stream(16, 71))
        .unwrap();
    let spec = FitSpec::smc(2048, 73);
    let n_threads = 4;
    let barrier = Barrier::new(n_threads);

    let results: Vec<_> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..n_threads {
            let (handle, spec, barrier) = (Arc::clone(&handle), spec.clone(), &barrier);
            joins.push(s.spawn(move || {
                barrier.wait(); // line up the cold misses
                handle.fit("normal_normal", &spec).unwrap()
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    let (first, _) = &results[0];
    for (art, _) in &results[1..] {
        assert!(
            Arc::ptr_eq(art, first),
            "every thread must serve the same fitted artifact"
        );
    }
    let stats = handle.stats();
    assert_eq!(stats.artifacts, 1, "one key, one artifact");
    assert!(stats.cache_misses >= 1);
    // every non-leader either blocked on the in-flight fit or arrived
    // late enough to hit the cache — nobody fitted a second time
    assert!(
        stats.single_flight_waits + stats.cache_hits >= (n_threads as u64) - 1,
        "waits {} + hits {} should cover the {} non-leaders",
        stats.single_flight_waits,
        stats.cache_hits,
        n_threads - 1
    );
}

#[test]
fn oversized_request_lines_get_a_json_error_and_a_closed_connection() {
    let handle = Arc::new(ServeHandle::new(ServeConfig {
        max_line_bytes: 256,
        ..ServeConfig::default()
    }));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&handle), 1).unwrap();
    let addr = server.local_addr().unwrap();
    let daemon = std::thread::spawn(move || server.run());

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let long = format!("{{\"op\": \"stats\", \"junk\": \"{}\"}}\n", "a".repeat(4096));
    writer.write_all(long.as_bytes()).unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let resp = Json::parse(resp.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(
        resp.get("error")
            .and_then(Json::as_str)
            .is_some_and(|e| e.contains("exceeds")),
        "error should name the byte cap: {resp:?}"
    );
    // the connection is closed after the violation, not resynchronized
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF");

    // an in-budget request on a fresh connection still works, then stop
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\": \"stats\"}\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert_eq!(
        Json::parse(resp.trim()).unwrap().get("ok").and_then(Json::as_bool),
        Some(true)
    );
    writer.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    daemon.join().unwrap().unwrap();
}

#[test]
fn stalled_connections_time_out_with_a_json_error() {
    let handle = Arc::new(ServeHandle::new(ServeConfig {
        request_timeout_ms: 150,
        ..ServeConfig::default()
    }));
    let server = Server::bind("127.0.0.1:0", Arc::clone(&handle), 1).unwrap();
    let addr = server.local_addr().unwrap();
    let daemon = std::thread::spawn(move || server.run());

    // connect and send nothing: the worker must come back on its own
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let resp = Json::parse(resp.trim()).unwrap();
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(
        resp.get("error")
            .and_then(Json::as_str)
            .is_some_and(|e| e.contains("timed out")),
        "error should name the timeout: {resp:?}"
    );
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF");
    drop(stream);

    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"op\": \"shutdown\"}\n").unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    daemon.join().unwrap().unwrap();
}

// ------------------------------------------- shared-cell compile safety

#[test]
fn concurrent_first_evaluations_compile_exactly_once() {
    // eight threads race their first fused evaluation over one shared
    // compile cell (the server-worker pattern): exactly one static
    // promotion, every thread serving bitwise-identical results
    let model = StreamNormal {
        y: vec![0.3, -0.5, 0.8, 0.1, 0.4],
    };
    let mut rng = Xoshiro256pp::seed_from_u64(61);
    let tvi = init_typed(&model, &mut rng);
    let theta = tvi.unconstrained.clone();
    let cell = NativeDensity::shared_cell();
    let n_threads = 8;
    let barrier = Barrier::new(n_threads);

    let results: Vec<(u64, Vec<u64>, u64)> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..n_threads {
            let cell = Arc::clone(&cell);
            let (model, tvi, theta, barrier) = (&model, &tvi, &theta, &barrier);
            joins.push(s.spawn(move || {
                let _ = metrics::take_local(); // fresh shard
                let ld = NativeDensity::fused_shared(model, tvi, cell);
                let mut grad = vec![0.0; tvi.dim()];
                barrier.wait(); // line up the first evaluations
                let lp = ld.logp_grad_into(&theta, &mut grad);
                let promotions = metrics::take_local().get(Counter::StaticPromotions);
                (
                    lp.to_bits(),
                    grad.iter().map(|g| g.to_bits()).collect(),
                    promotions,
                )
            }));
        }
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });

    let total_promotions: u64 = results.iter().map(|r| r.2).sum();
    assert_eq!(
        total_promotions, 1,
        "one shared cell must compile exactly once across all threads"
    );
    let (lp0, g0, _) = &results[0];
    for (lp, g, _) in &results[1..] {
        assert_eq!(lp, lp0, "log-density drifted across threads");
        assert_eq!(g, g0, "gradient drifted across threads");
    }
    // the cell is filled: a later density serves the program with no walk
    let ld = NativeDensity::fused_shared(&model, &tvi, cell);
    assert!(ld.compiled_program().is_some(), "promotion did not stick");
}
