//! End-to-end tests of the probability-query engine (paper §3.5): the four
//! query forms from the paper against the linear-regression model, with
//! hand-computed reference probabilities.

use dynamicppl::chain::Chain;
use dynamicppl::prelude::*;
use dynamicppl::query::{eval_query, Bindings, ModelRegistry, Query};

model! {
    /// linreg from the paper: s ~ InverseGamma(2,3), w ~ Normal(0,√s) iid,
    /// y[i] ~ Normal(x[i]·w, √s).
    pub LinReg {
        x: Vec<Vec<f64>>,
        y: Vec<f64>,
        dim: usize,
    }
    fn body<T>(this, api) {
        let s = tilde!(api, s ~ InverseGamma(c(2.0), c(3.0)));
        let sd = s.sqrt();
        let w = tilde_vec!(api, w ~ IsoNormal(c(0.0), sd, this.dim));
        for i in 0..this.y.len() {
            let mut mu = c::<T>(0.0);
            for j in 0..this.dim {
                mu = mu + w[j] * this.x[i][j];
            }
            obs!(api, this.y[i] => Normal(mu, sd));
        }
    }
}

fn registry() -> ModelRegistry {
    let mut reg = ModelRegistry::new();
    reg.register("linreg", |data: &Bindings| {
        let get = |name: &str| data.iter().find(|(k, _)| k == name).map(|(_, v)| v.clone());
        // X is a flat row-major matrix binding [x11, x12, ...] with ncol=2
        // for this test model; absent data ⇒ no observations.
        let x: Vec<Vec<f64>> = match get("X") {
            Some(Value::Vec(flat)) => flat.chunks(2).map(|c| c.to_vec()).collect(),
            _ => vec![],
        };
        let y: Vec<f64> = match get("y") {
            Some(Value::Vec(v)) => v,
            Some(Value::F64(v)) => vec![v],
            _ => vec![],
        };
        assert_eq!(x.len(), y.len(), "X rows must match y length");
        Box::new(LinReg { x, y, dim: 2 })
    });
    reg
}

#[test]
fn prior_query_matches_closed_form() {
    // prob"w = [1.0, 1.0], s = 1.0 | model = linreg"  (paper example 2)
    let q = Query::parse("w = [1.0, 1.0], s = 1.0 | model = linreg").unwrap();
    let r = eval_query(&q, &registry(), None).unwrap();
    let expect = InverseGamma::new(2.0, 3.0).logpdf(1.0)
        + IsoNormal::new(0.0, 1.0, 2).logpdf(&[1.0, 1.0]);
    assert!(
        (r.log_prob - expect).abs() < 1e-12,
        "{} vs {expect}",
        r.log_prob
    );
}

#[test]
fn likelihood_query_matches_closed_form() {
    // prob"X = ..., y = [2.0] | w = [0.5, 0.0], s = 1.0, model = linreg"
    // (paper example 1)
    let q = Query::parse("X = [1.0, 2.0], y = [2.0] | w = [0.5, 0.0], s = 1.0, model = linreg")
        .unwrap();
    let r = eval_query(&q, &registry(), None).unwrap();
    // mu = 0.5·1 + 0·2 = 0.5; N(2; 0.5, 1)
    let expect = Normal::new(0.5, 1.0).logpdf(2.0);
    assert!(
        (r.log_prob - expect).abs() < 1e-12,
        "{} vs {expect}",
        r.log_prob
    );
}

#[test]
fn joint_query_is_prior_plus_likelihood() {
    // prob"X = ..., y = [2.0], w = [0.0, 0.0], s = 1.0 | model = linreg"
    // (paper example 3)
    let q = Query::parse(
        "X = [1.0, 2.0], y = [2.0], w = [0.0, 0.0], s = 1.0 | model = linreg",
    )
    .unwrap();
    let r = eval_query(&q, &registry(), None).unwrap();
    let prior =
        InverseGamma::new(2.0, 3.0).logpdf(1.0) + IsoNormal::new(0.0, 1.0, 2).logpdf(&[0.0, 0.0]);
    let lik = Normal::new(0.0, 1.0).logpdf(2.0);
    assert!((r.log_prob - (prior + lik)).abs() < 1e-12);
}

#[test]
fn chain_query_is_posterior_predictive() {
    // prob"X = ..., y = [2.0] | chain, model = linreg"  (paper example 4)
    // Build a fake 2-draw chain and check the log-mean-exp average.
    let mut chain = Chain::new(vec!["s".into(), "w[0]".into(), "w[1]".into()]);
    chain.push(vec![1.0, 0.5, 0.0], 0.0);
    chain.push(vec![4.0, 1.0, -1.0], 0.0);
    let q = Query::parse("X = [1.0, 2.0], y = [2.0] | chain, model = linreg").unwrap();
    let r = eval_query(&q, &registry(), Some(&chain)).unwrap();
    let l1 = Normal::new(0.5, 1.0).logpdf(2.0); // draw 1: mu = 0.5, sd = 1
    let l2 = Normal::new(-1.0, 2.0).logpdf(2.0); // draw 2: mu = 1-2 = -1, sd = 2
    let expect = dynamicppl::util::math::log_sum_exp(&[l1, l2]) - 2f64.ln();
    assert!(
        (r.log_prob - expect).abs() < 1e-12,
        "{} vs {expect}",
        r.log_prob
    );
}

#[test]
fn missing_parameter_is_an_error() {
    let q = Query::parse("X = [1.0, 2.0], y = [2.0] | w = [0.5, 0.0], model = linreg").unwrap();
    let err = eval_query(&q, &registry(), None).unwrap_err();
    assert!(err.contains('s'), "{err}");
}

#[test]
fn unknown_model_is_an_error() {
    let q = Query::parse("s = 1.0 | model = nope").unwrap();
    assert!(eval_query(&q, &registry(), None).is_err());
}

#[test]
fn probabilities_exponentiate() {
    let q = Query::parse("w = [0.0, 0.0], s = 1.0 | model = linreg").unwrap();
    let r = eval_query(&q, &registry(), None).unwrap();
    assert!((r.prob() - r.log_prob.exp()).abs() < 1e-300);
    assert!(r.prob() > 0.0 && r.prob() < 1.0);
}
